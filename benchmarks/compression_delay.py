"""Beyond-paper ablation: uplink gradient compression × the delay model.

The paper charges every client s_c = 28.1 kbit per round for the LoRA-update
upload.  Top-k sparsification (+ error feedback, convergence-safe) shrinks
the uplink; re-running the paper's allocator with the compressed s_c
quantifies the end-to-end training-delay impact — an optimisation the paper
does not consider but its framework directly prices.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import resource_alloc as ra
from repro.core.compression import compressed_bits, dense_bits


def run(fractions=(1.0, 0.25, 0.1, 0.01), num_clients=50, seed=0, verbose=True,
        lora_params: int | None = None):
    """With the paper's own s_c = 28.1 kbit (a 281-param linear model) the
    uplink is negligible and compression gains ~0 % — an honest negative
    result.  With a *real LLM LoRA* upload (default: the fedsllm-100m
    adapter, ~1.6 M params = 52 Mbit fp32) the uplink dominates the round
    and top-k compression buys large delay reductions — the regime the
    paper's framework prices but does not explore."""
    if lora_params is None:
        from repro.config import get_arch
        from repro.core.lora import lora_param_count

        lora_params = lora_param_count(get_arch("fedsllm-100m"))
    base = FedsLLMConfig(num_clients=num_clients,
                         s_c_bits=float(lora_params * 32))
    net = dm.sample_network(base, seed=seed)
    rows = []
    for frac in fractions:
        if frac >= 1.0:
            s_c = base.s_c_bits
            tag = "dense_fp32"
        else:
            idx_bits = int(np.ceil(np.log2(max(lora_params, 2))))
            k = max(1, int(np.ceil(frac * lora_params)))
            s_c = k * (8 + idx_bits)  # int8 values + indices
            tag = f"topk_{frac:.2f}_int8"
        cfg = dataclasses.replace(base, s_c_bits=float(s_c))
        a = ra.optimize(cfg, net, "proposed", eta_search="coarse")
        rows.append(dict(tag=tag, s_c_bits=s_c, T=a.T, eta=a.eta))
        if verbose:
            print(f"{tag:18s} s_c={s_c/1e6:8.2f} Mbit  T*={a.T:9.1f}s  η*={a.eta:.2f}",
                  flush=True)
    if verbose and len(rows) > 1:
        print(f"\ncompression delay gain vs dense: "
              f"{100*(1 - rows[-1]['T']/rows[0]['T']):.2f}%")
    return rows


if __name__ == "__main__":
    run()
