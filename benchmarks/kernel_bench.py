"""Kernel micro-benchmarks.

On this CPU container Pallas kernels execute in interpret mode (Python), so
wall-times are NOT TPU-representative; what we report per kernel is
  * the jnp-reference wall time (compiled on CPU — a real baseline),
  * the analytic FLOPs and HBM bytes of the kernel's workload,
  * arithmetic intensity + the projected TPU-v5e roofline time
    max(flops/197e12, bytes/819e9) for the default production tile shapes —
    the number the §Perf iteration tracks.

Timing harness: every bench reports the MEDIAN of ``KERNEL_REPEATS``
back-to-back calls (median, not mean — one GC pause or scheduler hiccup
must not move the reported number), after a warm-up call that also absorbs
compilation.  ``python benchmarks/kernel_bench.py --variance`` runs each
bench ``--trials`` times and prints the relative spread of the medians —
the measurement that sized the per-entry ``"threshold"`` gates these
benches carry in ``benchmarks/BENCH_baseline.json`` (see
``benchmarks/compare.py``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
# repeats per reported median; raised from 5 after the CI-variance
# measurement (see --variance) so the kernel benches are stable enough to
# gate — the ms-scale CPU references swing far less at the median of 15
# than at a single call
KERNEL_REPEATS = 15


def _time(fn, *args, repeats=None):
    """Median wall-clock of ``repeats`` calls (compile+warm excluded)."""
    repeats = KERNEL_REPEATS if repeats is None else repeats
    jax.block_until_ready(fn(*args))  # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_lora(M=256, K=4096, N=4096, r=16, dtype=jnp.bfloat16, verbose=True):
    # default M=256: the fine-tuning microbatch / decode regime where the
    # matmul is HBM-bound and fusing the low-rank path saves real bytes
    # (at M>=2048 the op is MXU-bound and fusion is time-neutral)
    from repro.kernels.lora_ref import lora_matmul_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    a = jax.random.normal(ks[2], (K, r), dtype)
    b = jax.random.normal(ks[3], (r, N), dtype)
    ref = jax.jit(lora_matmul_ref)
    t = _time(ref, x, w, a, b)
    flops = 2 * M * K * N + 2 * M * K * r + 2 * M * r * N
    # fused kernel reads x once; unfused reads x twice + (M, r) roundtrip
    bytes_fused = (M * K + K * N + K * r + r * N + M * N) * 2
    bytes_unfused = bytes_fused + (M * K + 2 * M * r) * 2
    tpu_fused = max(flops / PEAK_FLOPS, bytes_fused / HBM_BW)
    tpu_unfused = max(flops / PEAK_FLOPS, bytes_unfused / HBM_BW)
    if verbose:
        print(f"lora_matmul M{M}xK{K}xN{N} r{r}: cpu_ref {t*1e3:.1f}ms | "
              f"AI={flops/bytes_fused:.0f} | v5e fused {tpu_fused*1e6:.1f}us vs "
              f"unfused {tpu_unfused*1e6:.1f}us ({100*(tpu_unfused/tpu_fused-1):.1f}% saved)")
    return dict(name="lora_matmul", cpu_ref_us=t * 1e6, tpu_roofline_us=tpu_fused * 1e6,
                tpu_unfused_us=tpu_unfused * 1e6)


def bench_attention(B=1, H=8, S=2048, d=128, verbose=True):
    from repro.kernels.attn_ref import flash_attention_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, d), jnp.bfloat16)
    ref = jax.jit(lambda *a: flash_attention_ref(*a))
    t = _time(ref, q, k, v)
    flops = 4 * B * H * S * S * d  # qk + pv (causal halves it; keep upper bound)
    bytes_flash = (3 * B * H * S * d + B * H * S * d) * 2
    bytes_naive = bytes_flash + 2 * B * H * S * S * 4  # logits roundtrip fp32
    tpu_flash = max(flops / PEAK_FLOPS, bytes_flash / HBM_BW)
    tpu_naive = max(flops / PEAK_FLOPS, bytes_naive / HBM_BW)
    if verbose:
        print(f"flash_attention B{B} H{H} S{S} d{d}: cpu_ref {t*1e3:.1f}ms | "
              f"v5e flash {tpu_flash*1e6:.1f}us vs naive {tpu_naive*1e6:.1f}us "
              f"({tpu_naive/tpu_flash:.1f}x)")
    return dict(name="flash_attention", cpu_ref_us=t * 1e6,
                tpu_roofline_us=tpu_flash * 1e6, tpu_naive_us=tpu_naive * 1e6)


def bench_ssd(B=2, S=2048, H=24, P=64, N=128, verbose=True):
    from repro.models.mamba2 import ssd_chunked

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.4
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.4
    fn = jax.jit(lambda *a: ssd_chunked(*a, chunk=256)[0])
    t = _time(fn, x, dt, A, Bm, Cm)
    Q = 256
    flops = B * H * (S * Q * N * 2 * 2 + S * Q * P * 2 + S * N * P * 4)
    if verbose:
        print(f"ssd_scan B{B} S{S} H{H} P{P} N{N}: cpu chunked {t*1e3:.1f}ms "
              f"({flops/1e9:.1f} GFLOP)")
    return dict(name="ssd_scan", cpu_ref_us=t * 1e6)


def measure_variance(trials: int = 4, repeats: int = None) -> dict[str, dict]:
    """Run every kernel bench ``trials`` times; report the medians' spread.

    The number that decides whether a bench is gateable: ``rel_spread`` =
    (max − min) / min over the trial medians.  A per-entry gate threshold
    should comfortably exceed it (we sized the committed thresholds at
    ≳3× the spread measured on the CI container class — re-run this after
    a runner change before chasing phantom regressions)."""
    global KERNEL_REPEATS
    if repeats is not None:
        KERNEL_REPEATS = repeats
    out = {}
    for fn, key in ((bench_lora, "cpu_ref_us"),
                    (bench_attention, "cpu_ref_us"),
                    (bench_ssd, "cpu_ref_us")):
        meds = [fn(verbose=False)[key] for _ in range(trials)]
        name = fn.__name__.removeprefix("bench_")
        out[name] = {
            "medians_us": [round(m, 1) for m in meds],
            "min_us": round(min(meds), 1), "max_us": round(max(meds), 1),
            "rel_spread": round((max(meds) - min(meds)) / min(meds), 4),
        }
        print(f"{name}: medians {out[name]['medians_us']} us, "
              f"spread {100*out[name]['rel_spread']:.1f}%", flush=True)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--variance", action="store_true",
                    help="measure run-to-run spread of each bench median")
    ap.add_argument("--trials", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=None,
                    help=f"calls per median (default {KERNEL_REPEATS})")
    args = ap.parse_args()
    if args.variance:
        measure_variance(trials=args.trials, repeats=args.repeats)
    else:
        if args.repeats:
            KERNEL_REPEATS = args.repeats
        bench_lora()
        bench_attention()
        bench_ssd()
