"""Benchmark harness — one entry per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline number
each benchmark exists to produce, e.g. Fig.2's %-reduction) and mirrors the
run machine-readably to ``results/BENCH_round.json`` (name →
{us_per_call, derived}) so the perf trajectory is diffable across PRs.

  fig2_delay      paper Fig. 2 (delay vs power, 4 strategies)  [the paper's
                  only results artifact]
  solver          exact Lemma-3 solver vs fmincon-equivalent NLP
  split_step      split-learning step vs monolithic autodiff (must match)
  fedsllm_round   one full Algorithm-1+2 global round (8 clients)
  campaign        multi-round campaign engine (resampled channels, elastic
                  cohort, deadline stragglers; must stay at 1 jit trace)
  des             event-driven execution schedules: pipelined-schedule
                  campaign vs sync (simulated-delay saving must be > 0)
  scale           mega-scale population campaigns (repro.pop): per-round
                  cost vs K ∈ {10³, 10⁴, 10⁵} at fixed cohort — must be
                  O(cohort); also writes results/BENCH_scale.json
  kernels         lora / attention / ssd micro-benches (median of
                  KERNEL_REPEATS calls; gated with per-entry thresholds)
  roofline        summary over dry-run artifacts (if present)
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           os.pardir, "results")

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def write_json(path: str = os.path.join(RESULTS_DIR, "BENCH_round.json")):
    """Machine-readable mirror of the CSV rows emitted this run.

    Merged into the existing file (a subset invocation like ``run.py
    campaign`` must refresh its own entries, not clobber the others)."""
    if not ROWS:
        return
    table: dict = {}
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        pass
    table.update({name: {"us_per_call": round(us, 1), "derived": derived}
                  for name, us, derived in ROWS})
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.relpath(path)} ({len(ROWS)}/{len(table)} entries "
          f"refreshed)", flush=True)


def bench_fig2():
    from benchmarks.fig2_delay import run

    t0 = time.time()
    s = run(powers_dbm=(0.0, 10.0, 20.0), num_clients=50, verbose=False)
    us = (time.time() - t0) * 1e6
    emit("fig2_delay", us / 3,
         f"avg_reduction_vs_BA={s['avg_reduction_vs_BA_pct']:.2f}%_paper=47.63%")


def bench_solver():
    from benchmarks.solver_bench import run

    rows = run(num_clients=(50,), repeats=3, verbose=False)
    r = rows[0]
    emit("solver_exact", r["exact_s"] * 1e6, f"T={r['exact_T']:.1f}s")
    emit("solver_scipy_fmincon_eq", r["scipy_s"] * 1e6,
         f"gap_vs_exact={r['gap_pct']:+.2f}%")


def bench_split_step():
    from repro.config import LoRAConfig, get_arch, smoke_variant
    from repro.core import lora as lora_lib, split
    from repro.models import transformer as T

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(0))
    lora, _ = lora_lib.init_lora(params, axes, cfg, key=jax.random.PRNGKey(1))
    lc, ls = lora_lib.split_client_server(lora, 1)
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks, "mask": jnp.ones((4, 64), jnp.float32)}
    fn = jax.jit(lambda lc, ls: split.split_value_and_grad(params, lc, ls, batch, cfg, 1)[0])
    fn(lc, ls).block_until_ready()
    t0 = time.perf_counter()
    n = 10
    for _ in range(n):
        fn(lc, ls).block_until_ready()
    us = (time.perf_counter() - t0) / n * 1e6
    mono = jax.jit(lambda lc, ls: split.monolithic_value_and_grad(params, lc, ls, batch, cfg, 1)[0])
    d = abs(float(fn(lc, ls)) - float(mono(lc, ls)))
    emit("split_step", us, f"split_vs_monolithic_loss_diff={d:.2e}")


def bench_fedsllm_round():
    from repro.api import Experiment
    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream, client_batches

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=8))
    exp = Experiment.from_config(run_cfg, eta=0.5, cut=1, allocator="EB")
    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)
    batches = client_batches(stream, 0, 8)
    res = exp.run_round(batches)  # compile
    jax.block_until_ready(res.state.lora_c)
    t0 = time.perf_counter()
    res = exp.run_round(batches)
    jax.block_until_ready(res.state.lora_c)
    us = (time.perf_counter() - t0) * 1e6
    emit("fedsllm_round_8clients", us,
         f"loss={float(res.metrics['loss_round_start']):.3f}_"
         f"round_sim={res.wall_clock:.2f}s")


def bench_campaign():
    """Experiment.run: N resampled-channel rounds through one jit trace."""
    from repro.api import Experiment
    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=8))
    exp = Experiment.from_config(run_cfg, eta=0.5, cut=1, allocator="EB")
    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)
    # deadline at the 75th percentile of the round-0 delays: slow clients
    # under later fades become stragglers instead of stretching the round
    deadline = float(np.quantile(exp.timing.total, 0.75))
    exp.run(num_rounds=1, stream=stream, cohort=4, deadline=deadline)  # compile
    t0 = time.perf_counter()
    # rounds are absolute: this continues at round 1 and runs two more
    res = exp.run(num_rounds=3, stream=stream, cohort=4, deadline=deadline,
                  resample_channel=True)
    jax.block_until_ready(res.state.lora_c)
    us = (time.perf_counter() - t0) / res.num_rounds * 1e6
    emit("campaign_round_8users_cohort4", us,
         f"traces={exp.trace_count}_stragglers={res.straggler_rate:.2f}_"
         f"sim={res.total_time:.1f}s")

    # joint-η reallocation: every round re-solves (16)/(17) on its own
    # channel draw and adopts the solved η (quantized to the η-bucket grid),
    # so the jit cache must stay bounded by the bucket count — the
    # acceptance bar for re-solving Lemma 1/2 jointly without recompiling
    exp2 = Experiment.from_config(run_cfg, eta=0.2, cut=1, allocator="EB",
                                  scenario="geo-blockfade")
    exp2.run(num_rounds=1, stream=stream, cohort=4, reallocate=True)  # compile
    t0 = time.perf_counter()
    res2 = exp2.run(num_rounds=4, stream=stream, cohort=4, reallocate=True)
    jax.block_until_ready(res2.state.lora_c)
    us2 = (time.perf_counter() - t0) / res2.num_rounds * 1e6
    buckets = len(exp2.eta_buckets)
    assert exp2.trace_count <= buckets, (exp2.trace_count, buckets)
    emit("campaign_realloc_joint_eta", us2,
         f"traces={exp2.trace_count}_eta_buckets={buckets}_"
         f"scenario=geo-blockfade_sim={res2.total_time:.1f}s")

    # joint-η reallocation under a QUEUED backhaul: the edge-cloud fifo
    # metro link turns on the allocator↔queueing fixed point
    # (net.allocation.solve_wait_aware) inside every per-round warm
    # re-solve.  At the default metro capacity the loop early-exits right
    # after the wait-blind iterate, so this prices the full wiring (per-η
    # hop evaluation + true-queue pricing) at its steady-state cost — and
    # the jit cache must stay η-bucket bounded exactly like the serial
    # reallocating campaign above
    from repro.net.topology import EdgeCloudTopology

    exp4 = Experiment.from_config(
        run_cfg, eta=0.2, cut=1, allocator="proposed",
        scenario="geo-blockfade",
        topology=EdgeCloudTopology(num_edges=2, backhaul_model="fifo"))
    exp4.run(num_rounds=1, stream=stream, cohort=4, reallocate=True)  # compile
    t0 = time.perf_counter()
    res4 = exp4.run(num_rounds=3, stream=stream, cohort=4, reallocate=True)
    jax.block_until_ready(res4.state.lora_c)
    us4 = (time.perf_counter() - t0) / res4.num_rounds * 1e6
    buckets4 = len(exp4.eta_buckets)
    assert exp4.trace_count <= buckets4, (exp4.trace_count, buckets4)
    diag = exp4.topology.wait_diag
    assert diag and all(d.converged for d in diag), diag
    emit("campaign_realloc_queued", us4,
         f"traces={exp4.trace_count}_eta_buckets={buckets4}_"
         f"topology=edge-cloud+fifo_wait_iters="
         f"{max(d.iters for d in diag)}_sim={res4.total_time:.1f}s")

    # SCAFFOLD carries (K, …) control variates through the same jitted round
    # (value-only gather/scatter): the derived number is its per-round cost
    # relative to the gd campaign above, and the trace count must stay 1
    exp3 = Experiment.from_config(run_cfg, eta=0.5, cut=1, allocator="EB",
                                  local_algo="scaffold")
    exp3.run(num_rounds=1, stream=stream, cohort=4, deadline=deadline)  # compile
    t0 = time.perf_counter()
    res3 = exp3.run(num_rounds=3, stream=stream, cohort=4, deadline=deadline,
                    resample_channel=True)
    jax.block_until_ready(res3.state.lora_c)
    us3 = (time.perf_counter() - t0) / res3.num_rounds * 1e6
    assert exp3.trace_count == 1, exp3.trace_count
    emit("campaign_scaffold", us3,
         f"overhead_vs_gd={100.0 * (us3 / us - 1.0):+.1f}%_traces=1")


def bench_des():
    """Event-driven schedules: a pipelined-schedule campaign vs sync.

    The derived number is the simulated-delay saving the microbatch overlap
    buys on identical rounds (the acceptance bar: strictly positive); the
    wall-clock entry (``campaign_pipelined``) rides the compare.py gate so
    a planner-path slowdown fails CI like any other hot path."""
    from repro.api import Experiment
    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=8))
    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)

    def campaign(schedule):
        exp = Experiment.from_config(run_cfg, eta=0.5, cut=1, allocator="EB",
                                     schedule=schedule)
        exp.run(num_rounds=1, stream=stream, cohort=4)  # compile
        t0 = time.perf_counter()
        res = exp.run(num_rounds=3, stream=stream, cohort=4)
        jax.block_until_ready(res.state.lora_c)
        us = (time.perf_counter() - t0) / res.num_rounds * 1e6
        assert exp.trace_count == 1, exp.trace_count
        return us, res

    us_sync, res_sync = campaign("sync")
    us_pipe, res_pipe = campaign("pipelined")
    saved = 100.0 * (1.0 - res_pipe.total_time / res_sync.total_time)
    assert res_pipe.total_time < res_sync.total_time, (
        res_pipe.total_time, res_sync.total_time)
    emit("campaign_pipelined", us_pipe,
         f"sim_saved_vs_sync={saved:.2f}%_sync_round={us_sync:.0f}us_traces=1")


def write_scale_json(per_round_us: dict, cohort: int,
                     path: str = os.path.join(RESULTS_DIR,
                                              "BENCH_scale.json")):
    """Top-level scale trajectory: rounds/sec vs K at fixed cohort.

    Merged into the existing file like ``write_json`` (other entries — e.g.
    future sync-family or sharded-mesh trajectories — must survive a
    ``run.py scale`` refresh)."""
    table: dict = {}
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        pass
    ks = sorted(per_round_us)
    table["megascale_async_meanfield"] = {
        "cohort": cohort,
        "schedule": "async",
        "topology": "edge-cloud+fifo",
        "population": "meanfield",
        "us_per_round": {str(k): round(per_round_us[k], 1) for k in ks},
        "rounds_per_sec": {str(k): round(1e6 / per_round_us[k], 3)
                           for k in ks},
        "ratio_Kmax_vs_Kmin": round(per_round_us[ks[-1]]
                                    / per_round_us[ks[0]], 3),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
    print(f"# wrote {os.path.relpath(path)}", flush=True)


def bench_scale():
    """Mega-scale population campaigns: per-round cost must be O(cohort).

    The same async edge-cloud+fifo campaign under the ``meanfield``
    population at K = 10³, 10⁴, 10⁵ simulated clients with a fixed cohort,
    frozen channel (``resample_channel=False`` — the constructor's one
    exact K-sized solve + queue pricing is the per-campaign cost; each
    round then costs only the window batch, the O(cohort) compaction and
    the O(C) timeline).  The gate entry is the K=10⁵ per-round wall-clock;
    the derived ratio vs K=10³ is the O(cohort) acceptance bar (the ISSUE
    asks < 2x at equal cohort)."""
    from repro.api import Experiment
    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream
    from repro.net.topology import EdgeCloudTopology

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)
    cohort = 8
    per_round_us: dict[int, float] = {}
    for K in (1_000, 10_000, 100_000):
        run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                            fedsllm=FedsLLMConfig(num_clients=K))
        exp = Experiment.from_config(
            run_cfg, eta=0.5, cut=1, allocator="EB",
            scenario="geo-blockfade", schedule="async",
            topology=EdgeCloudTopology(num_edges=8, backhaul_model="fifo"),
            population="meanfield")
        exp.run(num_rounds=1, stream=stream, cohort=cohort,
                resample_channel=False)  # compile at (cohort, …)
        t0 = time.perf_counter()
        res = exp.run(num_rounds=4, stream=stream, cohort=cohort,
                      resample_channel=False)
        jax.block_until_ready(res.state.lora_c)
        per_round_us[K] = (time.perf_counter() - t0) / res.num_rounds * 1e6
        assert exp.trace_count == 1, exp.trace_count
        assert all(len(r.client_ids) == cohort for r in res.records)
    ratio = per_round_us[100_000] / per_round_us[1_000]
    emit("campaign_megascale", per_round_us[100_000],
         f"K=1e5_cohort={cohort}_round_cost_vs_K1e3={ratio:.2f}x_traces=1")
    write_scale_json(per_round_us, cohort)


def bench_kernels():
    from benchmarks.kernel_bench import bench_attention, bench_lora, bench_ssd

    r = bench_lora(verbose=False)
    emit("kernel_lora_matmul_cpu_ref", r["cpu_ref_us"],
         f"v5e_fused={r['tpu_roofline_us']:.1f}us_vs_unfused={r['tpu_unfused_us']:.1f}us")
    r = bench_attention(verbose=False)
    emit("kernel_flash_attention_cpu_ref", r["cpu_ref_us"],
         f"v5e_flash={r['tpu_roofline_us']:.1f}us_vs_naive={r['tpu_naive_us']:.1f}us")
    r = bench_ssd(verbose=False)
    emit("kernel_ssd_scan_cpu_chunked", r["cpu_ref_us"], "chunked=MXU-friendly")


def bench_pipeline():
    """Split-learning microbatch pipelining speedup under §IV channel draws."""
    import numpy as np

    from repro.config import FedsLLMConfig
    from repro.core import delay_model as dm
    from repro.core import resource_alloc as ra
    from repro.parallel import pipeline

    fcfg = FedsLLMConfig(num_clients=20)
    net = dm.sample_network(fcfg, seed=0)
    t0 = time.time()
    a = ra.solve_fixed_eta_exact(fcfg, net, 0.1)
    stages = pipeline.split_stage_times(fcfg, net, 0.1, a.A, a)
    out = pipeline.pipeline_round_time(stages, 8)
    us = (time.time() - t0) * 1e6
    emit("split_pipeline_m8", us,
         f"median_speedup={float(np.median(out['speedup'])):.2f}x")


def bench_compression():
    from benchmarks.compression_delay import run

    t0 = time.time()
    rows = run(fractions=(1.0, 0.1), num_clients=20, verbose=False)
    us = (time.time() - t0) * 1e6 / len(rows)
    gain = 100 * (1 - rows[-1]["T"] / rows[0]["T"])
    emit("compression_delay", us, f"topk10pct_delay_gain={gain:.2f}%")


def bench_roofline():
    try:
        from benchmarks.roofline import load_table

        rows = load_table()
        if not rows:
            emit("roofline", 0.0, "no_dryrun_artifacts_yet")
            return
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        best = max(rows, key=lambda r: r["roofline_fraction"])
        emit("roofline_cells", float(len(rows)),
             f"best={best['arch']}/{best['shape']}={100*best['roofline_fraction']:.1f}%_"
             f"worst={worst['arch']}/{worst['shape']}={100*worst['roofline_fraction']:.1f}%")
    except Exception as e:  # artifacts optional for the harness
        emit("roofline", 0.0, f"unavailable:{type(e).__name__}")


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    print("name,us_per_call,derived")
    if which in ("all", "solver"):
        bench_solver()
    if which in ("all", "split"):
        bench_split_step()
    if which in ("all", "round"):
        bench_fedsllm_round()
    if which in ("all", "campaign"):
        bench_campaign()
    if which in ("all", "des"):
        bench_des()
    if which in ("all", "scale"):
        bench_scale()
    if which in ("all", "kernels"):
        bench_kernels()
    if which in ("all", "pipeline"):
        bench_pipeline()
    if which in ("all", "compression"):
        bench_compression()
    if which in ("all", "fig2"):
        bench_fig2()
    if which in ("all", "roofline"):
        bench_roofline()
    write_json()


if __name__ == "__main__":
    main()
