"""Paper Fig. 2 reproduction: minimum training latency vs maximum
transmission power, for the four strategies (Proposed / EB / FE / BA).

The paper reports the proposed optimiser reduces delay by an average of
47.63% vs the unoptimised BA strategy across the power sweep.  This
benchmark reproduces the experiment (50 users, 500 m cell, 20 MHz, FDMA,
BlogFeedback sizing) and prints the per-power latencies + the measured
average reduction.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.api import allocators
from repro.config import FedsLLMConfig
from repro.core import delay_model as dm

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run(powers_dbm=(0.0, 5.0, 10.0, 15.0, 20.0), num_clients=50, seeds=(0,),
        eta_search="coarse", verbose=True):
    cfg = FedsLLMConfig(num_clients=num_clients)
    rows = []
    for p in powers_dbm:
        for seed in seeds:
            net = dm.sample_network(cfg, seed=seed, p_max_dbm=p)
            t0 = time.time()
            prop = allocators.get("proposed")(cfg, net, eta_search=eta_search)
            eb = allocators.get("EB")(cfg, net)
            fe = allocators.get("FE")(cfg, net)
            ba = allocators.get("BA")(cfg, net)
            row = dict(p_dbm=p, seed=seed, proposed=prop.T, EB=eb.T, FE=fe.T,
                       BA=ba.T, eta_star=prop.eta, solve_s=time.time() - t0)
            rows.append(row)
            if verbose:
                print(f"p={p:5.1f}dBm seed={seed}: proposed={prop.T:9.1f}s "
                      f"EB={eb.T:9.1f}s FE={fe.T:9.1f}s BA={ba.T:9.1f}s "
                      f"(η*={prop.eta:.2f}, {row['solve_s']:.1f}s)", flush=True)
    red = [1 - r["proposed"] / r["BA"] for r in rows]
    summary = {
        "rows": rows,
        "avg_reduction_vs_BA_pct": 100 * float(np.mean(red)),
        "paper_claim_pct": 47.63,
        "avg_reduction_vs_EB_pct": 100 * float(np.mean([1 - r["proposed"] / r["EB"] for r in rows])),
        "avg_reduction_vs_FE_pct": 100 * float(np.mean([1 - r["proposed"] / r["FE"] for r in rows])),
    }
    if verbose:
        print(f"\naverage reduction vs BA: {summary['avg_reduction_vs_BA_pct']:.2f}% "
              f"(paper: 47.63%)")
        print(f"average reduction vs EB: {summary['avg_reduction_vs_EB_pct']:.2f}%")
        print(f"average reduction vs FE: {summary['avg_reduction_vs_FE_pct']:.2f}%")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-faithful 0.01-step η sweep (slow)")
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    summary = run(num_clients=args.clients, seeds=tuple(range(args.seeds)),
                  eta_search="grid" if args.full else "coarse")
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "fig2_delay.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(f"saved -> results/fig2_delay.json")


if __name__ == "__main__":
    main()
