"""Perf-trajectory gate: diff BENCH_round.json against a committed baseline.

``benchmarks/run.py`` mirrors every bench run to ``results/BENCH_round.json``
(name → {us_per_call, derived}).  This tool compares that file against the
committed ``benchmarks/BENCH_baseline.json`` and exits non-zero when any
shared entry's wall-clock regressed more than ``--threshold`` (default 10%,
ROADMAP open item #2) — CI runs it right after the campaign smoke, so a PR
that slows a hot path fails loudly instead of drifting.

New entries (benches the baseline predates) and removed entries are
reported but never fail the gate; refresh the baseline deliberately with
``--update`` after an intentional perf change.  Caveat: the committed
baseline encodes the wall-clock of the machine that blessed it — if the CI
runner class changes (or proves noisier than 10%), re-bless the baseline
from a CI run's uploaded BENCH_round artifact (or raise ``--threshold``)
rather than chasing phantom regressions.

Per-entry thresholds: a baseline entry may carry its own ``"threshold"``
field, overriding ``--threshold`` for that entry — used for benches whose
measured run-to-run variance exceeds the 10% default.  Measured over four
back-to-back runs of ``run.py solver`` on one machine: ``solver_exact``
swung 1.30/1.57/1.32/1.69 s (~30% — sub-2 s of host numpy, sensitive to
machine load), ``solver_scipy_fmincon_eq`` held within ~5%.  Hence
``solver_exact`` gates at 50% (a real algorithmic regression — e.g. losing
the Lambert-W closed form — is a multiple, not a percentage) and
``solver_scipy_fmincon_eq`` at 25%.  ``campaign_pipelined`` (the des
schedule bench) gates at 30% like the other campaign-scale entries' spread
suggests.  ``--update`` preserves the per-entry thresholds already in the
baseline.

The kernel micro-benches (``kernel_*``) are gated since the schedules PR:
``kernel_bench.py`` reports the median of ``KERNEL_REPEATS=15`` calls, and
``kernel_bench.py --variance`` measured the medians' run-to-run spread on
this container class under a concurrent test load (representative of
shared CI runners): lora ~17%, attention ~28%, ssd ~26% over 4 trials.
The committed per-entry thresholds sit at roughly 3× / 2.5× that spread —
lora 50%, attention 75%, ssd 75%: a real kernel regression (an accidental
fp32 upcast, a lost fusion) is a multiple, not tens of percent.  The
entries are hundreds of ms, far above the ``--min-us`` floor, so the gate
bites on real regressions while staying dark on scheduler noise; the
analytic v5e roofline projections in ``derived`` are unaffected by machine
speed.  Re-run ``--variance`` before re-sizing a threshold.

    PYTHONPATH=src:. python benchmarks/run.py solver
    PYTHONPATH=src python benchmarks/run.py campaign
    PYTHONPATH=src python benchmarks/run.py des
    PYTHONPATH=src:. python benchmarks/run.py kernels
    PYTHONPATH=src python benchmarks/compare.py            # gate
    PYTHONPATH=src python benchmarks/compare.py --update   # bless current
"""

from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_CURRENT = os.path.join(HERE, os.pardir, "results", "BENCH_round.json")
DEFAULT_BASELINE = os.path.join(HERE, "BENCH_baseline.json")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(current: dict, baseline: dict, threshold: float,
            min_us: float) -> tuple[list[str], list[str]]:
    """Returns (report lines, regression names)."""
    lines, regressions = [], []
    for name in sorted(set(current) | set(baseline)):
        cur = current.get(name)
        base = baseline.get(name)
        if cur is None:
            lines.append(f"  - {name}: only in baseline (not run)")
            continue
        if base is None:
            lines.append(f"  + {name}: new ({cur['us_per_call']:.1f} us) — "
                         f"baseline it with --update")
            continue
        b, c = float(base["us_per_call"]), float(cur["us_per_call"])
        # a noisy bench can carry its own gate width in the baseline
        thr = float(base.get("threshold", threshold))
        delta = (c - b) / b if b > 0 else 0.0
        tag = "ok"
        if c > b * (1.0 + thr) and c - b > min_us:
            tag = "REGRESSION"
            regressions.append(name)
        elif c < b * (1.0 - thr):
            tag = "improved"
        note = f" [gate {thr:.0%}]" if thr != threshold else ""
        lines.append(f"  {name}: {b:.1f} -> {c:.1f} us ({delta:+.1%}) "
                     f"{tag}{note}")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="this run's BENCH_round.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline to diff against")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative wall-clock regression that fails the gate")
    ap.add_argument("--min-us", type=float, default=1000.0,
                    help="ignore regressions smaller than this many µs "
                         "(sub-ms benches are timer noise)")
    ap.add_argument("--update", action="store_true",
                    help="bless the current results as the new baseline")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"no current results at {args.current} — run benchmarks/run.py "
              f"first", file=sys.stderr)
        return 2
    if args.update:
        current = load(args.current)
        try:  # keep the per-entry gate widths of the old baseline
            for name, entry in load(args.baseline).items():
                if "threshold" in entry and name in current:
                    current[name]["threshold"] = entry["threshold"]
        except (OSError, ValueError):
            pass
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
        print(f"baseline updated: {os.path.relpath(args.baseline)}")
        return 0
    if not os.path.exists(args.baseline):
        print(f"no committed baseline at {args.baseline} — create one with "
              f"--update", file=sys.stderr)
        return 2

    lines, regressions = compare(load(args.current), load(args.baseline),
                                 args.threshold, args.min_us)
    print(f"bench diff vs {os.path.relpath(args.baseline)} "
          f"(threshold {args.threshold:.0%}):")
    print("\n".join(lines))
    if regressions:
        print(f"FAIL: {len(regressions)} wall-clock regression(s) "
              f">{args.threshold:.0%}: {', '.join(regressions)}",
              file=sys.stderr)
        return 1
    print("OK: no wall-clock regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
