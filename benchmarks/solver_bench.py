"""Solver benchmark: paper-faithful NLP (scipy SLSQP ≈ MATLAB fmincon
interior-point) vs the beyond-paper exact Lemma-3 structured solver.

Reports wall-time per fixed-η solve and the optimality gap (the exact solver
must match or beat the NLP optimum — it solves the same convex problem with
the structure of Lemma 3 exploited)."""

from __future__ import annotations

import time

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import resource_alloc as ra


def run(num_clients=(10, 25, 50), eta=0.1, repeats=3, verbose=True):
    rows = []
    for K in num_clients:
        cfg = FedsLLMConfig(num_clients=K)
        net = dm.sample_network(cfg, seed=0)
        t_ex, t_sp = [], []
        for _ in range(repeats):
            t0 = time.time(); ex = ra.solve_fixed_eta_exact(cfg, net, eta); t_ex.append(time.time() - t0)
            t0 = time.time(); sp = ra.solve_fixed_eta_scipy(cfg, net, eta); t_sp.append(time.time() - t0)
        row = dict(K=K, exact_s=float(np.median(t_ex)), scipy_s=float(np.median(t_sp)),
                   exact_T=ex.T, scipy_T=sp.T, gap_pct=100 * (sp.T - ex.T) / ex.T)
        rows.append(row)
        if verbose:
            print(f"K={K:3d}: exact {row['exact_s']*1e3:8.1f}ms (T={ex.T:9.2f})  "
                  f"scipy {row['scipy_s']*1e3:8.1f}ms (T={sp.T:9.2f})  "
                  f"NLP is {row['gap_pct']:+.2f}% worse", flush=True)
    return rows


if __name__ == "__main__":
    run()
