"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch × shape) single-pod cell, computes the three terms from the
composed (scan-corrected) per-device HLO costs:

    compute_s    = flops_per_device / 197e12        (bf16 MXU peak, v5e)
    memory_s     = bytes_per_device / 819e9         (HBM bandwidth)
    collective_s = collective_bytes_per_device / 50e9  (ICI per-link)

plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train,
2·N(_active)·D for prefill, 2·N·B for decode, and the usefulness ratio
MODEL_FLOPS / HLO_FLOPS (catches remat/redundancy waste).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops(rec) -> float:
    """Global model flops for the cell's step (see module docstring)."""
    N = rec["params_active"]
    kind = rec["kind"]
    tokens = rec["seq_len"] * rec["global_batch"]
    if kind == "train":
        return 6.0 * N * tokens
    if kind == "prefill":
        return 2.0 * N * tokens
    return 2.0 * N * rec["global_batch"]  # decode: one token per row


def analyse_cell(rec) -> dict | None:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    src = rec.get("composed") or {
        "flops_per_device": rec["full"]["flops_per_device"],
        "bytes_per_device": rec["full"]["bytes_per_device"],
        "s2_bytes_per_device": rec["full"].get("s2_bytes_per_device", 0.0),
        "collective_bytes_per_device": rec["full"]["collectives"]["bytes_per_device"],
    }
    n_dev = rec["num_devices"]
    compute_s = src["flops_per_device"] / PEAK_FLOPS
    memory_s = src["bytes_per_device"] / HBM_BW
    # flash-kernel-adjusted memory: S²-shaped (attention-logit) op traffic
    # stays in VMEM when the Pallas flash kernel runs on real TPU
    s2 = src.get("s2_bytes_per_device", 0.0)
    memory_s_flash = max(src["bytes_per_device"] - s2, 0.0) / HBM_BW
    coll_s = src["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s_flash, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = src["flops_per_device"] * n_dev
    bound_s = max(terms.values())
    # roofline fraction: useful model flops per device-second at the bound
    mfu_at_bound = (mf / n_dev / PEAK_FLOPS) / bound_s if bound_s > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_s_flash": memory_s_flash, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": mfu_at_bound,
        "hbm_gb_per_device": rec["full"]["memory"]["total_hbm_bytes"] / 1e9,
    }


def load_table(mesh: str = "single") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyse_cell(rec)
        if row:
            rows.append(row)
    return rows


def print_table(rows, file=None):
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'mem_flash':>10s} {'collect_s':>10s} {'bound':>10s} {'useful':>7s} "
           f"{'roofline%':>9s} {'HBM_GB':>7s}")
    print(hdr, file=file)
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r.get('memory_s_flash', r['memory_s']):10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>10s} {r['useful_ratio']:7.3f} "
              f"{100*r['roofline_fraction']:9.2f} {r['hbm_gb_per_device']:7.2f}",
              file=file)


def main():
    rows = load_table()
    print_table(rows)
    out = os.path.join(DRYRUN_DIR, "..", "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nsaved -> results/roofline.json ({len(rows)} cells)")


if __name__ == "__main__":
    main()
