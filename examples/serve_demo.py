"""Batched serving demo: prefill + decode across three model families
(dense / SSM / hybrid) with KV- and state-caches.

    PYTHONPATH=src python examples/serve_demo.py
"""

import time

import jax

from repro.config import get_arch, smoke_variant
from repro.models import transformer as T
from repro.serving.decode import decode_tokens


def main():
    for arch in ("fedsllm-100m", "mamba2-130m", "recurrentgemma-9b"):
        cfg = smoke_variant(get_arch(arch))
        params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
        B, Sp, new = 4, 16, 12
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, Sp), 0, cfg.vocab_size)
        t0 = time.time()
        out = decode_tokens(params, cfg, prompt, max_new=new)
        dt = time.time() - t0
        print(f"{arch:22s} family={cfg.family:7s} batch={B} "
              f"generated {out.shape[1]} tokens/row in {dt:5.2f}s "
              f"({B*new/dt:6.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
