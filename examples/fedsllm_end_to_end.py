"""End-to-end FedsLLM (the paper, in one script) via the unified API:

  1. sample the wireless network of §IV (50 users, 500 m cell, FDMA),
  2. run the delay-minimisation allocator (problem (17) + η sweep) to get
     (T*, η*, b*, t*) — and the EB/FE/BA baselines for comparison, each a
     named strategy in the ``repro.api.allocators`` registry,
  3. run a *multi-round campaign* (``Experiment.run``): per-round channel
     evolution under a named scenario (``--scenario geo-blockfade`` keeps the
     user geometry fixed and redraws only the fading; ``drift``/``hetero``/
     ``outage`` add mobility, device tiers, fade bursts), an elastic 8-of-50
     cohort, and a round deadline that turns slow realisations into
     masked-out stragglers — the fed server aggregates survivors only
     (Algorithm 1's masked reduction),
  4. report: convergence + simulated total training delay under each policy.

    PYTHONPATH=src python examples/fedsllm_end_to_end.py
    PYTHONPATH=src python examples/fedsllm_end_to_end.py --scenario drift
    PYTHONPATH=src python examples/fedsllm_end_to_end.py \
        --topology edge-cloud --scenario geo-blockfade
    PYTHONPATH=src python examples/fedsllm_end_to_end.py \
        --schedule pipelined          # or: async / semi-async (no barrier)
    PYTHONPATH=src python examples/fedsllm_end_to_end.py \
        --local-algo scaffold --workload dirichlet   # drift-corrected non-IID
"""

import argparse
import time

import numpy as np

from repro.api import (Experiment, allocators, get_local_algo, get_schedule,
                       get_scenario, get_topology, get_workload, local_algos,
                       scenarios, schedules, topologies, workloads)
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.data.tokens import TokenStream

COHORT = 8  # clients trained per round (of the K=50 simulated radio users)
ROUNDS = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="blockfade",
                    help=f"channel dynamics, one of {scenarios.names()}")
    ap.add_argument("--topology", default="star",
                    help=f"network graph, one of {topologies.names()}; "
                         f"non-star needs a geometry scenario "
                         f"(e.g. --scenario geo-blockfade)")
    ap.add_argument("--schedule", default="sync",
                    help=f"execution discipline, one of {schedules.names()}; "
                         f"pipelined overlaps client/server microbatches, "
                         f"async/semi-async drop the round barrier and "
                         f"aggregate arrivals staleness-weighted")
    ap.add_argument("--local-algo", default="gd",
                    help=f"client local-update rule, one of "
                         f"{local_algos.names()}; fedprox/scaffold correct "
                         f"for client drift under non-IID workloads")
    ap.add_argument("--workload", default="iid",
                    help=f"per-client data distribution, one of "
                         f"{workloads.names()}")
    args = ap.parse_args()
    # unknown names fail fast with the knowns listed, like every registry
    scenario = get_scenario(args.scenario)
    topology = get_topology(args.topology)
    schedule = get_schedule(args.schedule)
    local_algo = get_local_algo(args.local_algo)
    workload = get_workload(args.workload)

    # --- model: LoRA-adapted small LM, split at A_min of the depth ---------
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    fcfg = FedsLLMConfig(num_clients=50)

    # --- paper §IV wireless simulation + problem (17), every strategy ------
    # (hierarchical graphs re-anchor each client on its attached edge and
    # solve per edge cell — the same registry strategies, combined)
    net, assign = topology.localize(fcfg, scenario.initial_network(fcfg,
                                                                   seed=0))
    alloc = {}
    for strat in allocators.names():  # BA / EB / FE / proposed
        alloc[strat] = topology.allocate(fcfg, net, assign,
                                         allocators.get(strat),
                                         strategy=strat, eta_search="coarse")
        print(f"  {strat:9s}: T*={alloc[strat].T:10.1f}s  η={alloc[strat].eta:.2f}")
    best = alloc["proposed"]
    print(f"  reduction vs BA: {100*(1-best.T/alloc['BA'].T):.2f}% (paper avg: 47.63%)")

    # --- multi-round campaign under η*, one Experiment (reusing the network
    # realisation + allocation solved above — no second η sweep).  Rounds
    # evolve the channel per the scenario; the stale allocation is re-priced
    # under each draw, and clients missing the deadline are masked out. -----
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"], fedsllm=fcfg)
    exp = Experiment.from_config(run_cfg, allocator="proposed", net=net,
                                 alloc=best, scenario=scenario,
                                 topology=topology, schedule=schedule,
                                 local_algo=local_algo, workload=workload)
    print(exp.describe())
    deadline = float(np.quantile(exp.timing.total, 0.8))  # cuts slowest ~20%

    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)
    t0 = time.time()

    def log(rec):
        print(f"round {rec.round}: cohort {rec.client_ids.tolist()} "
              f"survivors {rec.survivors}/{rec.cohort_size}  "
              f"loss {rec.metrics['loss_round_start']:.4f} "
              f"-> {rec.metrics['loss_local_final']:.4f}   "
              f"simulated wall-clock {rec.cumulative_time:9.1f}s", flush=True)

    res = exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT,
                  deadline=deadline, resample_channel=True, on_round=log)

    ba_round = float(np.max(
        topology.round_timing(fcfg, net, alloc["BA"], 0.1, assign).total))
    print(f"\n{res.num_rounds} rounds in {time.time()-t0:.1f}s real, "
          f"{res.total_time:.1f}s simulated wireless time, "
          f"straggler rate {res.straggler_rate:.1%}, "
          f"{exp.trace_count} jit trace "
          f"(BA policy would need {ROUNDS*ba_round:.1f}s)")


if __name__ == "__main__":
    main()
