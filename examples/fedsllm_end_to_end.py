"""End-to-end FedsLLM (the paper, in one script) via the unified API:

  1. sample the wireless network of §IV (50 users, 500 m cell, FDMA),
  2. run the delay-minimisation allocator (problem (17) + η sweep) to get
     (T*, η*, b*, t*) — and the EB/FE/BA baselines for comparison, each a
     named strategy in the ``repro.api.allocators`` registry,
  3. fine-tune an LM with LoRA under the *split federated* Algorithm 1+2
     through one ``Experiment`` object, which charges each global round the
     simulated wireless wall-clock from the allocation,
  4. report: convergence + simulated total training delay under each policy.

    PYTHONPATH=src python examples/fedsllm_end_to_end.py
"""

import time

import numpy as np

from repro.api import Experiment, allocators
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import delay_model as dm
from repro.core import fedsllm
from repro.data.tokens import TokenStream, client_batches

CLIENTS = 8  # cohort actually trained (of the K=50 simulated radio users)
ROUNDS = 8


def main():
    # --- model: LoRA-adapted small LM, split at A_min of the depth ---------
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    fcfg = FedsLLMConfig(num_clients=50)

    # --- paper §IV wireless simulation + problem (17), every strategy ------
    net = dm.sample_network(fcfg, seed=0)
    alloc = {}
    for strat in allocators.names():  # BA / EB / FE / proposed
        alloc[strat] = allocators.get(strat)(fcfg, net, eta_search="coarse")
        print(f"  {strat:9s}: T*={alloc[strat].T:10.1f}s  η={alloc[strat].eta:.2f}")
    best = alloc["proposed"]
    print(f"  reduction vs BA: {100*(1-best.T/alloc['BA'].T):.2f}% (paper avg: 47.63%)")

    # --- split-fed training under η*, one Experiment (reusing the network
    # realisation + allocation solved above — no second η sweep) ------------
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"], fedsllm=fcfg)
    exp = Experiment.from_config(run_cfg, allocator="proposed", net=net, alloc=best)
    print(exp.describe())

    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)
    simulated = 0.0
    t0 = time.time()
    for r in range(ROUNDS):
        batches = client_batches(stream, r, CLIENTS)
        res = exp.run_round(batches)
        simulated += res.wall_clock
        print(f"round {r}: loss {float(res.metrics['loss_round_start']):.4f} "
              f"-> {float(res.metrics['loss_local_final']):.4f}   "
              f"simulated wall-clock {simulated:9.1f}s", flush=True)
    ba_round = float(np.max(
        fedsllm.simulate_round_time(fcfg, net, alloc["BA"], 0.1).total))
    print(f"\n{ROUNDS} rounds in {time.time()-t0:.1f}s real, "
          f"{simulated:.1f}s simulated wireless time "
          f"(BA policy would need {ROUNDS*ba_round:.1f}s)")


if __name__ == "__main__":
    main()
