"""End-to-end FedsLLM (the paper, in one script):

  1. sample the wireless network of §IV (50 users, 500 m cell, FDMA),
  2. run the delay-minimisation allocator (problem (17) + η sweep) to get
     (T*, η*, b*, t*) — and the EB/FE/BA baselines for comparison,
  3. fine-tune an LM with LoRA under the *split federated* Algorithm 1+2,
     using η* to set the local-iteration count, and charge each global round
     the simulated wireless wall-clock from the allocation,
  4. report: convergence + simulated total training delay under each policy.

    PYTHONPATH=src python examples/fedsllm_end_to_end.py
"""

import time

import jax
import numpy as np

from repro.config import FedsLLMConfig, LoRAConfig, get_arch, smoke_variant
from repro.core import delay_model as dm
from repro.core import fedsllm, resource_alloc as ra
from repro.core.lora import lora_param_count
from repro.data.tokens import TokenStream, client_batches

CLIENTS = 8  # cohort actually trained (of the K=50 simulated radio users)
ROUNDS = 8


def main():
    # --- model: LoRA-adapted small LM, split at A_min of the depth ---------
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    fcfg = FedsLLMConfig(num_clients=50)
    cut = max(1, int(round(fcfg.split_ratio_min * cfg.num_groups)))
    print(f"model {cfg.name}: {cfg.num_groups} groups, cut at {cut} "
          f"(A≈{cut/cfg.num_groups:.2f}), LoRA params {lora_param_count(cfg):,}")

    # --- paper §IV wireless simulation + problem (17) ----------------------
    net = dm.sample_network(fcfg, seed=0)
    alloc = {}
    for strat in ("proposed", "EB", "FE", "BA"):
        alloc[strat] = ra.optimize(fcfg, net, strat, eta_search="coarse")
        print(f"  {strat:9s}: T*={alloc[strat].T:10.1f}s  η={alloc[strat].eta:.2f}")
    best = alloc["proposed"]
    print(f"  reduction vs BA: {100*(1-best.T/alloc['BA'].T):.2f}% (paper avg: 47.63%)")

    # --- split-fed training under η* ---------------------------------------
    eta = float(best.eta)
    state, _ = fedsllm.init_state(cfg, cut)
    round_fn = jax.jit(fedsllm.make_round_fn(cfg, fcfg, cut, eta=min(eta, 0.5)))
    stream = TokenStream(2, 64, cfg.vocab_size, seed=0)
    timing = fedsllm.simulate_round_time(fcfg, net, best, eta)
    round_wall = float(np.max(timing.total))

    simulated = 0.0
    t0 = time.time()
    for r in range(ROUNDS):
        batches = client_batches(stream, r, CLIENTS)
        state, metrics = round_fn(state, batches)
        simulated += round_wall
        print(f"round {r}: loss {float(metrics['loss_round_start']):.4f} "
              f"-> {float(metrics['loss_local_final']):.4f}   "
              f"simulated wall-clock {simulated:9.1f}s", flush=True)
    print(f"\n{ROUNDS} rounds in {time.time()-t0:.1f}s real, "
          f"{simulated:.1f}s simulated wireless time "
          f"(BA policy would need {ROUNDS*float(np.max(fedsllm.simulate_round_time(fcfg, net, alloc['BA'], 0.1).total)):.1f}s)")


if __name__ == "__main__":
    main()
