"""Resource-allocation demo: reproduce the shape of the paper's Fig. 2 on a
reduced grid and show the Lemma-3 structure of the optimal solution.

    PYTHONPATH=src python examples/resource_allocation_demo.py
"""

import numpy as np

from repro.api import allocators
from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import resource_alloc as ra


def main():
    cfg = FedsLLMConfig(num_clients=20)
    print("power   proposed        EB        FE        BA    η*")
    reductions = []
    for p_dbm in (0.0, 10.0, 20.0):
        net = dm.sample_network(cfg, seed=0, p_max_dbm=p_dbm)
        prop = allocators.get("proposed")(cfg, net, eta_search="coarse")
        eb = allocators.get("EB")(cfg, net)
        fe = allocators.get("FE")(cfg, net)
        ba = allocators.get("BA")(cfg, net)
        reductions.append(1 - prop.T / ba.T)
        print(f"{p_dbm:5.1f} {prop.T:9.1f} {eb.T:9.1f} {fe.T:9.1f} {ba.T:9.1f}"
              f"   {prop.eta:.2f}")
    print(f"\navg reduction vs BA: {100*np.mean(reductions):.2f}%  (paper: 47.63%)")

    # Lemma 3 structure at the optimum
    net = dm.sample_network(cfg, seed=0)
    a = ra.solve_fixed_eta_exact(cfg, net, 0.1)
    V = dm.local_iters(cfg, 0.1)
    I0 = dm.global_rounds(cfg, 0.1)
    R = a.T / I0 - dm.compute_time(cfg, net, 0.1, a.A)
    print("\nLemma 3 checks at the optimum:")
    print("  max |t_c + V·t_s − budget| =", float(np.max(np.abs(a.t_c + V * a.t_s - R))))
    print("  bandwidth budgets used:   ",
          f"fed {a.b_c.sum()/net.B_c*100:.1f}%  main {a.b_s.sum()/net.B_s*100:.1f}%")
    print("  worst-channel user gets   ",
          f"{a.b_s[np.argmin(net.g_s)]/np.mean(a.b_s):.2f}x mean main-server bandwidth")


if __name__ == "__main__":
    main()
