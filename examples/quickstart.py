"""Quickstart: train a small decoder LM for a few steps and generate, then
run the same model through the unified FedsLLM ``Experiment`` API (split +
federated + simulated wireless) in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.api import Experiment
from repro.config import (FedsLLMConfig, RunConfig, SHAPES, TrainConfig,
                          get_arch, smoke_variant)
from repro.data.tokens import TokenStream, client_batches
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.serving.decode import decode_tokens


def main():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(vocab_size=512)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=60, warmup_steps=10,
                       remat="none")
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    step_fn, opt = make_train_step(cfg, tcfg)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    stream = TokenStream(batch=8, seq=64, vocab=cfg.vocab_size, seed=0)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    first = last = None
    for i in range(tcfg.total_steps):
        params, opt_state, step, metrics = jit_step(params, opt_state, step,
                                                    stream.batch_at(i))
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 10 == 0:
            print(f"step {i:3d}  loss {loss:.4f}")
    print(f"\nloss {first:.3f} -> {last:.3f} (structured synthetic stream)")

    prompt = stream.batch_at(999)["tokens"][:2, :8]
    out = decode_tokens(params, cfg, prompt, max_new=8)
    print("generated:", out[0].tolist())

    # --- the same model, federated + split, via the unified API ------------
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=4))
    exp = Experiment.from_config(run_cfg, allocator="EB")
    res = exp.run_round(client_batches(stream, 0, exp.cohort))
    print(f"\nfederated round via Experiment: loss "
          f"{float(res.metrics['loss_round_start']):.3f} -> "
          f"{float(res.metrics['loss_local_final']):.3f}, "
          f"simulated round wall-clock {res.wall_clock:.2f}s")


if __name__ == "__main__":
    main()
