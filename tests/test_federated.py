"""FedAvg invariants (hypothesis property tests) + straggler handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import federated

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _stack(arrs):
    return {"w": jnp.stack([jnp.asarray(a, jnp.float32) for a in arrs])}


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=2, max_size=8))
def test_identical_clients_fixed_point(vals):
    """FedAvg of identical client updates returns the same update."""
    K = 4
    arr = np.asarray(vals, np.float32)
    tree = {"w": jnp.tile(jnp.asarray(arr)[None], (K, 1))}
    avg = federated.fedavg(tree)
    np.testing.assert_allclose(np.asarray(avg["w"]), arr, rtol=1e-6, atol=1e-30)


@given(st.integers(2, 8), st.integers(0, 1000))
def test_permutation_invariance(K, seed):
    rng = np.random.default_rng(seed)
    arrs = rng.normal(size=(K, 5)).astype(np.float32)
    perm = rng.permutation(K)
    a1 = federated.fedavg(_stack(arrs))
    a2 = federated.fedavg(_stack(arrs[perm]))
    np.testing.assert_allclose(np.asarray(a1["w"]), np.asarray(a2["w"]), atol=1e-5)


@given(st.integers(2, 8), st.integers(0, 1000))
def test_mean_in_convex_hull(K, seed):
    rng = np.random.default_rng(seed)
    arrs = rng.normal(size=(K, 3)).astype(np.float32)
    avg = np.asarray(federated.fedavg(_stack(arrs))["w"])
    assert np.all(avg <= arrs.max(axis=0) + 1e-5)
    assert np.all(avg >= arrs.min(axis=0) - 1e-5)


@given(st.integers(3, 8), st.integers(0, 100))
def test_mask_excludes_stragglers(K, seed):
    rng = np.random.default_rng(seed)
    arrs = rng.normal(size=(K, 4)).astype(np.float32)
    arrs[0] = 1e6  # poisoned straggler
    mask = jnp.asarray([0.0] + [1.0] * (K - 1))
    avg = np.asarray(federated.fedavg(_stack(arrs), mask=mask)["w"])
    np.testing.assert_allclose(avg, arrs[1:].mean(axis=0), rtol=1e-4)


def test_weighted_by_data_size():
    """Paper eq. (3): aggregation weighted by D_k."""
    arrs = np.array([[1.0, 1.0], [3.0, 3.0]], np.float32)
    w = jnp.asarray([1.0, 3.0])
    avg = np.asarray(federated.fedavg(_stack(arrs), weights=w)["w"])
    np.testing.assert_allclose(avg, [2.5, 2.5], rtol=1e-6)


def test_apply_update_and_broadcast_roundtrip():
    g = {"w": jnp.ones((3,))}
    K = 5
    b = federated.broadcast(g, K)
    assert jax.tree.leaves(b)[0].shape == (K, 3)
    h = jax.tree.map(lambda x: x * 0.5, b)
    new = federated.apply_update(g, federated.fedavg(h))
    np.testing.assert_allclose(np.asarray(new["w"]), 1.5)


@given(K=st.integers(3, 8), seed=st.integers(0, 1000), drop=st.integers(0, 7))
def test_mask_invariance_to_straggler_batch_content(K, seed, drop):
    """A masked-out client's update contents must not change the aggregate:
    whatever a straggler computed (or garbage it uploaded) is irrelevant once
    the deadline mask zeroes it — for every registered aggregator.

    (Aggregators iterated inside the body: the offline hypothesis fallback
    hides the signature, which defeats @pytest.mark.parametrize.)"""
    from repro.api import aggregators

    drop = drop % K
    rng = np.random.default_rng(seed)
    clean = rng.normal(size=(K, 5)).astype(np.float32)
    poisoned = clean.copy()
    poisoned[drop] = rng.normal(scale=1e6, size=5).astype(np.float32)
    mask = np.ones(K, np.float32)
    mask[drop] = 0.0
    weights = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    for name in aggregators.names():
        agg = aggregators.get(name)
        a = agg(_stack(clean), weights=weights, mask=jnp.asarray(mask))
        b = agg(_stack(poisoned), weights=weights, mask=jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]),
                                      err_msg=f"aggregator {name!r}")


@given(K=st.integers(3, 8), seed=st.integers(0, 1000))
def test_deadline_mask_drops_exactly_over_deadline(K, seed):
    """deadline_mask over simulated round delays keeps exactly the clients
    meeting the deadline (the campaign engine's straggler wiring)."""
    rng = np.random.default_rng(seed)
    T_k = rng.uniform(0.1, 10.0, K)
    deadline = float(np.median(T_k))
    m = federated.deadline_mask(T_k, deadline)
    np.testing.assert_array_equal(m, (T_k <= deadline).astype(np.float32))
    assert m.sum() >= 1  # the median itself always survives


def test_all_straggler_round_yields_zero_update():
    """A round where EVERY client misses the deadline must contribute a zero
    update under every aggregator — never NaN (which would poison the state
    for the rest of the campaign)."""
    from repro.api import aggregators

    tree = _stack(np.random.default_rng(0).normal(size=(4, 3)).astype(np.float32))
    mask = jnp.zeros(4)
    for name in aggregators.names():
        out = aggregators.get(name)(tree, mask=mask)
        np.testing.assert_array_equal(np.asarray(out["w"]), 0.0,
                                      err_msg=f"aggregator {name!r}")


def test_deadline_mask():
    T_k = np.array([1.0, 5.0, 2.0])
    m = federated.deadline_mask(T_k, 2.5)
    np.testing.assert_array_equal(m, [1.0, 0.0, 1.0])


def test_client_sample_deterministic():
    s1 = federated.client_sample(3, 50, 10, seed=7)
    s2 = federated.client_sample(3, 50, 10, seed=7)
    np.testing.assert_array_equal(s1, s2)
    assert len(np.unique(s1)) == 10


# ---------------------------------------------------------------------------
# Two-tier (hier_aggregate): the segment_sum fast path
# ---------------------------------------------------------------------------


def _hier_fixture(K=7, M=3, seed=0):
    rng = np.random.default_rng(seed)
    assign = jnp.asarray(np.eye(M, dtype=np.float32)[rng.integers(0, M, K)])
    # LoRA-shaped leaves in both fp32 and bf16, like the real update trees
    tree = {"a": jnp.asarray(rng.normal(size=(K, 4, 2)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(K, 5)).astype(np.float32)
                             ).astype(jnp.bfloat16)}
    weights = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    mask = jnp.asarray((rng.uniform(size=K) > 0.3).astype(np.float32))
    return tree, assign, weights, mask


def test_hier_aggregate_segment_bitequal_unrolled():
    """The segment_sum fast path must reproduce the unrolled M-loop
    BIT-exactly for every mean-family aggregator, with and without
    weights/mask (zeros added by the masked full-K sums are exact no-ops,
    and member contributions accumulate in the same client order)."""
    from repro.api import aggregators

    tree, assign, weights, mask = _hier_fixture()
    for name in ("fedavg", "weighted", "staleness"):
        agg = aggregators.get(name)
        assert getattr(agg, "mean_family", None) is not None
        for w, m in ((None, None), (weights, None), (None, mask),
                     (weights, mask)):
            fast = federated.hier_aggregate(agg, tree, assign,
                                            weights=w, mask=m)
            slow = federated.hier_aggregate_unrolled(agg, tree, assign,
                                                     weights=w, mask=m)
            for leaf in tree:
                np.testing.assert_array_equal(
                    np.asarray(fast[leaf], np.float32),
                    np.asarray(slow[leaf], np.float32),
                    err_msg=f"{name} leaf={leaf} w={w is not None} "
                            f"m={m is not None}")


def test_hier_aggregate_robust_still_unrolled_and_equal():
    """Robust aggregators (no mean_family marker) keep the per-edge order
    statistic — the dispatch must leave their results untouched."""
    from repro.api import aggregators

    tree, assign, weights, mask = _hier_fixture(seed=1)
    for name in ("median", "trimmed_mean"):
        agg = aggregators.get(name)
        assert getattr(agg, "mean_family", None) is None
        out = federated.hier_aggregate(agg, tree, assign, weights=weights,
                                       mask=mask)
        ref = federated.hier_aggregate_unrolled(agg, tree, assign,
                                                weights=weights, mask=mask)
        for leaf in tree:
            np.testing.assert_array_equal(np.asarray(out[leaf], np.float32),
                                          np.asarray(ref[leaf], np.float32))


def test_hier_aggregate_no_trace_growth_at_m64():
    """The fast path's jaxpr is independent of the edge count within each
    regime (the ROADMAP scaling item): the batched branch costs the same
    trace at M=4 and M=32, the segment_sum branch the same at M=33 and
    M=64 — while the unrolled loop would grow linearly."""
    from repro.api import aggregators

    rng = np.random.default_rng(0)
    K = 8
    tree = {"w": jnp.asarray(rng.normal(size=(K, 3)).astype(np.float32))}
    weights = jnp.ones(K)
    agg = aggregators.get("weighted")

    def eqns(M, aggregate):
        assign = jnp.asarray(
            np.eye(M, dtype=np.float32)[rng.integers(0, M, K)])
        jaxpr = jax.make_jaxpr(
            lambda t, w: federated.hier_aggregate(aggregate, t, assign, w)
        )(tree, weights)
        return len(jaxpr.jaxpr.eqns)

    assert eqns(4, agg) == eqns(32, agg)  # batched branch
    assert eqns(33, agg) == eqns(64, agg)  # segment_sum branch


def test_hier_aggregate_segment_branch_matches_to_float_association():
    """Above SEGMENT_MIN_EDGES the scatter-add branch takes over: it agrees
    with the unrolled loop to float associativity (a scatter accumulates
    members sequentially, a vectorised reduce builds a SIMD tree), and is
    EXACT whenever every cell has ≤ 2 contributors."""
    from repro.api import aggregators

    rng = np.random.default_rng(2)
    K, M = 24, 40
    assert M > federated.SEGMENT_MIN_EDGES
    ids = rng.integers(0, M, K)
    assign = jnp.asarray(np.eye(M, dtype=np.float32)[ids])
    tree = {"w": jnp.asarray(rng.normal(size=(K, 6)).astype(np.float32))}
    weights = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    agg = aggregators.get("weighted")
    fast = federated.hier_aggregate(agg, tree, assign, weights=weights)
    slow = federated.hier_aggregate_unrolled(agg, tree, assign,
                                             weights=weights)
    np.testing.assert_allclose(np.asarray(fast["w"]), np.asarray(slow["w"]),
                               rtol=1e-6)


def test_hier_aggregate_scale_k1e4_m256():
    """The mega-scale regime (ISSUE: 10⁴ clients, 256 edges): the
    segment_sum branch keeps its numerics against the unrolled reference
    at population scale (rtol — scatter vs SIMD-tree association, with a
    masked straggler fraction riding along), and the jaxpr stays
    M-independent all the way to M=256 at K=10⁴ — the property that makes
    the in-trace aggregation O(1) in the edge count for compacted
    mega-campaigns."""
    from repro.api import aggregators

    rng = np.random.default_rng(3)
    K, M = 10_000, 256
    assert M > federated.SEGMENT_MIN_EDGES
    ids = rng.integers(0, M, K)
    assign = jnp.asarray(np.eye(M, dtype=np.float32)[ids])
    tree = {"w": jnp.asarray(rng.normal(size=(K, 4)).astype(np.float32))}
    weights = jnp.asarray(rng.uniform(0.5, 2.0, K).astype(np.float32))
    mask = jnp.asarray((rng.uniform(size=K) > 0.2).astype(np.float32))
    agg = aggregators.get("weighted")
    fast = federated.hier_aggregate(agg, tree, assign, weights=weights,
                                    mask=mask)
    slow = federated.hier_aggregate_unrolled(agg, tree, assign,
                                             weights=weights, mask=mask)
    np.testing.assert_allclose(np.asarray(fast["w"]), np.asarray(slow["w"]),
                               rtol=2e-5)

    def eqns(M_):
        a = jnp.asarray(np.eye(M_, dtype=np.float32)[rng.integers(0, M_, K)])
        jaxpr = jax.make_jaxpr(
            lambda t, w, m: federated.hier_aggregate(agg, t, a, w, mask=m)
        )(tree, weights, mask)
        return len(jaxpr.jaxpr.eqns)

    assert eqns(64) == eqns(256)
