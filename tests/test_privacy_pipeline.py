"""DP aggregation, noise layer, split-learning pipelining, grad accumulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedsLLMConfig, LoRAConfig, TrainConfig, get_arch, smoke_variant
from repro.core import federated, privacy
from repro.core import lora as lora_lib, split
from repro.models import transformer as T
from repro.optim.grad_utils import global_norm
from repro.parallel import pipeline


# ---------------------------------------------------------------------------
# privacy
# ---------------------------------------------------------------------------


def test_clip_bounds_norm():
    t = {"w": jnp.full((10,), 100.0)}
    c = privacy.clip_tree(t, 1.0)
    np.testing.assert_allclose(float(global_norm(c)), 1.0, rtol=1e-5)
    # small updates pass through
    t2 = {"w": jnp.full((10,), 1e-3)}
    c2 = privacy.clip_tree(t2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["w"]), 1e-3, rtol=1e-5)


def test_dp_fedavg_noise_scale():
    """Mean of noised stack == clean mean + N(0, (σc/K)²)."""
    K, d = 8, 4096
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.normal(0, 0.01, (K, d)), jnp.float32)}
    noised = privacy.clip_and_noise_updates(stacked, jax.random.PRNGKey(0),
                                            clip_norm=1.0, noise_multiplier=1.0)
    clean = federated.fedavg(stacked)
    dp = federated.fedavg(noised)
    resid = np.asarray(dp["w"] - clean["w"])
    emp_std = resid.std()
    np.testing.assert_allclose(emp_std, 1.0 / K, rtol=0.15)


def test_noise_layer_snr():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    y = privacy.noise_layer(x, jax.random.PRNGKey(1), snr_db=20.0)
    noise = np.asarray(y - x)
    snr = float(jnp.mean(x**2)) / max(noise.var(), 1e-12)
    assert 50 < snr < 200  # 20 dB = 100x


def test_privacy_cost_monotone():
    e1 = privacy.privacy_cost(1.0, rounds=10)
    e2 = privacy.privacy_cost(2.0, rounds=10)
    e3 = privacy.privacy_cost(1.0, rounds=40)
    assert e2 < e1 < e3


def test_dp_round_runs_and_stays_finite():
    from repro.core import fedsllm
    from repro.data.tokens import TokenStream, client_batches

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    fcfg = FedsLLMConfig(num_clients=4)
    state, _ = fedsllm.init_state(cfg, 1)
    round_fn = jax.jit(fedsllm.build_round_fn(cfg, fcfg, 1, eta=0.5,
                                             dp_clip=1.0, dp_noise=0.5))
    stream = TokenStream(2, 32, cfg.vocab_size, seed=0)
    batches = client_batches(stream, 0, 4)
    state2, metrics = round_fn(state, batches, None, jax.random.PRNGKey(7))
    for leaf in jax.tree.leaves(state2.lora_c):
        assert bool(jnp.all(jnp.isfinite(leaf)))


# ---------------------------------------------------------------------------
# pipelining
# ---------------------------------------------------------------------------


def test_pipelined_split_grads_exact():
    """Microbatched split step == full-batch split step exactly."""
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4))
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(0))
    lora, _ = lora_lib.init_lora(params, axes, cfg, key=jax.random.PRNGKey(1))
    lora = jax.tree.map(lambda x: x + 0.01, lora)
    lc, ls = lora_lib.split_client_server(lora, 1)
    B, S = 4, 16
    kt, kl = jax.random.split(jax.random.PRNGKey(2))
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
             "mask": jnp.ones((B, S), jnp.float32)}
    loss_f, dc_f, ds_f, _ = split.split_value_and_grad(params, lc, ls, batch, cfg, 1)
    loss_p, dc_p, ds_p = pipeline.pipelined_split_grads(params, lc, ls, batch, cfg, 1, 4)
    np.testing.assert_allclose(float(loss_p), float(loss_f), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(dc_p), jax.tree.leaves(dc_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b, np.float32),
                                   rtol=1e-4, atol=5e-6)
    for a, b in zip(jax.tree.leaves(ds_p), jax.tree.leaves(ds_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b, np.float32),
                                   rtol=1e-4, atol=5e-6)


def test_pipeline_latency_model():
    stages = dict(client_fwd=1.0, uplink=0.5, server=2.0, downlink=0.1,
                  client_bwd=1.0)
    seq = pipeline.pipeline_round_time(stages, 1)
    pipe = pipeline.pipeline_round_time(stages, 8)
    assert np.isclose(seq["sequential_s"], 4.6)
    # M→∞ limit is the bottleneck stage (2.0)
    assert pipe["pipelined_s"] < seq["sequential_s"]
    assert pipe["pipelined_s"] >= 2.0 * (8 - 1) / 8
    assert pipe["speedup"] > 1.5


def test_pipeline_stage_times_integrate_with_allocator():
    from repro.core import delay_model as dm
    from repro.core import resource_alloc as ra

    fcfg = FedsLLMConfig(num_clients=5)
    net = dm.sample_network(fcfg, seed=0)
    a = ra.solve_fixed_eta_exact(fcfg, net, 0.1)
    stages = pipeline.split_stage_times(fcfg, net, 0.1, a.A, a)
    out = pipeline.pipeline_round_time(stages, 4)
    assert np.all(out["speedup"] >= 1.0)
    assert np.all(out["pipelined_s"] <= out["sequential_s"] + 1e-9)


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------


def test_grad_accumulation_matches_full_batch():
    from repro.launch.steps import make_train_step

    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(vocab_size=64)
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    kt, kl = jax.random.split(jax.random.PRNGKey(1))
    B, S = 8, 16
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
             "mask": jnp.ones((B, S), jnp.float32)}

    outs = {}
    for m in (0, 4):
        tcfg = TrainConfig(learning_rate=1e-2, remat="none", microbatch=m,
                           optimizer="sgd")
        step_fn, opt = make_train_step(cfg, tcfg)
        p, o, s, metrics = jax.jit(step_fn)(params, opt.init(params),
                                            jnp.zeros((), jnp.int32), batch)
        outs[m] = (p, float(metrics["loss"]))
    np.testing.assert_allclose(outs[0][1], outs[4][1], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)
