"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn_ops import flash_attention, flash_attention_ref
from repro.kernels.lora_ops import lora_matmul, lora_matmul_ref
from repro.kernels.ssd_ops import ssd_scan, ssd_scan_ref

# ---------------------------------------------------------------------------
# LoRA fused matmul
# ---------------------------------------------------------------------------

LORA_CASES = [
    # (M, K, N, r, dtype, tol)
    (128, 256, 128, 8, jnp.float32, 1e-5),
    (256, 512, 384, 16, jnp.float32, 1e-5),
    (64, 128, 256, 4, jnp.bfloat16, 5e-2),
    (100, 200, 300, 8, jnp.float32, 1e-5),  # non-aligned -> padding path
    (32, 1024, 64, 32, jnp.float32, 1e-5),
    (8, 64, 8, 2, jnp.float32, 1e-5),  # tiny
]


@pytest.mark.parametrize("M,K,N,r,dtype,tol", LORA_CASES)
def test_lora_matmul_matches_ref(M, K, N, r, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype) * 0.05
    a = jax.random.normal(ks[2], (K, r), dtype) * 0.05
    b = jax.random.normal(ks[3], (r, N), dtype) * 0.05
    y = lora_matmul(x, w, a, b, scale=2.0)
    ref = lora_matmul_ref(x, w, a, b, scale=2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_lora_matmul_batched_leading_dims():
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (2, 8, 64), jnp.float32)
    w = jax.random.normal(ks[1], (64, 32), jnp.float32) * 0.1
    a = jax.random.normal(ks[2], (64, 4), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (4, 32), jnp.float32) * 0.1
    y = lora_matmul(x, w, a, b)
    ref = lora_matmul_ref(x.reshape(16, 64), w, a, b).reshape(2, 8, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_lora_matmul_zero_B_equals_base():
    """B = 0 (LoRA init) -> fused result == plain matmul."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(ks[0], (64, 128), jnp.float32)
    w = jax.random.normal(ks[1], (128, 64), jnp.float32) * 0.1
    a = jax.random.normal(ks[2], (128, 8), jnp.float32)
    b = jnp.zeros((8, 64), jnp.float32)
    y = lora_matmul(x, w, a, b, scale=4.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, Kv, S, d, window, softcap, dtype, tol)
    (2, 4, 2, 128, 64, 0, 0.0, jnp.float32, 2e-5),
    (1, 4, 4, 256, 32, 64, 0.0, jnp.float32, 2e-5),   # sliding window
    (1, 2, 1, 128, 64, 0, 50.0, jnp.float32, 2e-5),   # softcap + MQA
    (1, 8, 2, 192, 64, 0, 0.0, jnp.bfloat16, 3e-2),   # GQA bf16, ragged seq
    (2, 2, 2, 64, 128, 32, 30.0, jnp.float32, 2e-5),  # window + softcap
]


@pytest.mark.parametrize("B,H,Kv,S,d,window,softcap,dtype,tol", ATTN_CASES)
def test_flash_attention_matches_ref(B, H, Kv, S, d, window, softcap, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Kv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Kv, S, d), dtype)
    o = flash_attention(q, k, v, window=window, softcap=softcap, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_rows_sum_to_one_property():
    """Degenerate v = ones -> output rows must be exactly ones (softmax sums)."""
    B, H, S, d = 1, 2, 128, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, d))
    v = jnp.ones((B, H, S, d))
    o = flash_attention(q, k, v, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, N, chunk, dtype, rtol)
    (2, 64, 3, 16, 8, 16, jnp.float32, 1e-4),
    (1, 128, 2, 32, 16, 32, jnp.float32, 1e-4),
    (1, 64, 1, 8, 8, 64, jnp.float32, 1e-4),   # single chunk
    (2, 96, 2, 16, 8, 32, jnp.float32, 1e-4),
]


@pytest.mark.parametrize("B,S,H,P,N,chunk,dtype,rtol", SSD_CASES)
def test_ssd_scan_matches_sequential_ref(B, S, H, P, N, chunk, dtype, rtol):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), dtype))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), dtype) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N), dtype) * 0.5
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    np.testing.assert_allclose(np.asarray(y) / scale, np.asarray(ref) / scale,
                               rtol=rtol, atol=rtol)


def test_ssd_decay_property():
    """With A -> -inf (full decay) the SSD reduces to a per-step product
    y_t = C_t·(dt_t·B_t ⊗ x_t) — no state carry-over."""
    B, S, H, P, N = 1, 32, 1, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jnp.full((B, S, H), 1.0)
    A = jnp.full((H,), -50.0)  # decay exp(-50) ≈ 0
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    expected = jnp.einsum("bsn,bsn,bshp->bshp", Cm, Bm, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected), rtol=1e-4, atol=1e-4)
