"""Checkpointer: atomicity, retention, corruption quarantine, resume."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def tree(v=1.0):
    return {"a": jnp.full((4, 4), v), "b": {"c": jnp.arange(8)}}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(10, tree(2.0), {"note": "x"})
    restored, meta = ck.restore()
    np.testing.assert_allclose(np.asarray(restored["a"]), 2.0)
    assert meta["step"] == 10 and meta["note"] == "x"


def test_retention_policy(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, tree(float(s)))
    assert ck.steps() == [3, 4]


def test_numpy_metadata_roundtrips(tmp_path):
    """Campaign metadata carries numpy scalars (simulated times, rounds);
    saving must coerce them to JSON instead of raising TypeError."""
    ck = Checkpointer(str(tmp_path))
    ck.save(3, tree(), {"cumulative_time": np.float32(12.5),
                        "round": np.int64(3),
                        "mask": np.array([1.0, 0.0])})
    _, meta = ck.restore()
    assert meta["cumulative_time"] == 12.5
    assert meta["round"] == 3 and meta["mask"] == [1.0, 0.0]


def test_corruption_quarantine_falls_back(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, tree(1.0))
    ck.save(2, tree(2.0))
    # corrupt the newest checkpoint
    path = os.path.join(str(tmp_path), "step_0000000002", "arrays.npz")
    with open(path, "wb") as f:
        f.write(b"garbage")
    restored, meta = ck.restore()
    assert meta["step"] == 1
    np.testing.assert_allclose(np.asarray(restored["a"]), 1.0)


def test_partial_write_invisible(tmp_path):
    """A dir without COMMITTED marker is never listed (atomicity)."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, tree())
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009"))
    assert ck.steps() == [5]


def test_restore_or_none_empty(tmp_path):
    ck = Checkpointer(str(tmp_path))
    assert ck.restore_or_none() is None


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto explicit shardings (single-device here; the same code
    path reshards onto any mesh)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree(3.0))
    mesh = make_mesh((1,), ("data",))
    sh = {"a": NamedSharding(mesh, P()), "b": {"c": NamedSharding(mesh, P())}}
    restored, _ = ck.restore(shardings=sh)
    np.testing.assert_allclose(np.asarray(restored["a"]), 3.0)
