"""Integration tests: train->crash->resume, end-to-end loss descent,
fedsllm + compression round, small-mesh dry-run sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.config import TrainConfig, get_arch, smoke_variant
from repro.data.tokens import TokenStream
from repro.launch.steps import make_train_step
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(vocab_size=128)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=30, warmup_steps=5,
                       remat="none")
    return cfg, tcfg


def run_steps(cfg, tcfg, params, opt_state, step, stream, lo, hi, jit_step, ckpt=None):
    losses = []
    for i in range(lo, hi):
        params, opt_state, step, m = jit_step(params, opt_state, step,
                                              stream.batch_at(i))
        losses.append(float(m["loss"]))
        if ckpt is not None:
            ckpt.save(i + 1, (params, opt_state, step))
    return params, opt_state, step, losses


def test_train_crash_resume_bitexact(setup, tmp_path):
    """Training N steps straight == training with a crash+restore midway."""
    cfg, tcfg = setup
    stream = TokenStream(2, 32, cfg.vocab_size, seed=1)
    step_fn, opt = make_train_step(cfg, tcfg)
    jit_step = jax.jit(step_fn)

    def fresh():
        params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
        return params, opt.init(params), jnp.zeros((), jnp.int32)

    # straight run: 8 steps
    p1, o1, s1 = fresh()
    p1, o1, s1, _ = run_steps(cfg, tcfg, p1, o1, s1, stream, 0, 8, jit_step)

    # crashed run: 4 steps -> checkpoint -> "crash" -> restore -> 4 more
    ck = Checkpointer(str(tmp_path))
    p2, o2, s2 = fresh()
    p2, o2, s2, _ = run_steps(cfg, tcfg, p2, o2, s2, stream, 0, 4, jit_step)
    ck.save(4, (p2, o2, s2))
    del p2, o2, s2
    (p2, o2, s2), meta = ck.restore()
    assert meta["step"] == 4
    p2, o2, s2, _ = run_steps(cfg, tcfg, p2, o2, s2, stream, 4, 8, jit_step)

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_loss_descends_on_structured_stream(setup):
    cfg, tcfg = setup
    stream = TokenStream(4, 48, cfg.vocab_size, seed=0, structure=1.0)
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    step_fn, opt = make_train_step(cfg, tcfg)
    jit_step = jax.jit(step_fn)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    params, opt_state, step, losses = run_steps(cfg, tcfg, params, opt_state,
                                                step, stream, 0, 30, jit_step)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_compression_in_fedsllm_round(setup):
    """Top-k + error-feedback applied to the client update between rounds:
    updates stay finite and the error memory is the exact residual."""
    from repro.core import compression

    cfg, _ = setup
    g = {"u": jax.random.normal(jax.random.PRNGKey(0), (256,))}
    sparse, err, bits = compression.compress_tree(g, 0.1)
    assert bits < compression.dense_bits(g)
    np.testing.assert_allclose(np.asarray(sparse["u"] + err["u"]),
                               np.asarray(g["u"]), rtol=1e-6)


def test_small_mesh_lowering_sanity(setup):
    """The production step lowers under a (1,1) mesh with the train ruleset
    (the same code path the 256-chip dry-run exercises)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import specs as SP, steps as ST
    from repro.launch.mesh import make_mesh
    from repro.parallel import RULESETS, sharding_context
    from repro.config import ShapeConfig

    cfg, tcfg = setup
    mesh = make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("tiny", "train", 32, 2)
    with sharding_context(mesh, RULESETS["train"]):
        params, axes = T.init_params(cfg, abstract=True)
        psh = ST.param_shardings(axes, params, mesh, RULESETS["train"])
        step_fn, opt = ST.make_train_step(cfg, tcfg)
        opt_state = ST.abstract_opt_state(opt, params)
        batch = SP.train_batch_specs(cfg, shape)
        bsh = ST.batch_shardings(batch, mesh, RULESETS["train"], "train")
        lowered = jax.jit(step_fn,
                          in_shardings=(psh, {k: psh for k in opt_state},
                                        NamedSharding(mesh, P()), bsh)).lower(
            params, opt_state, jax.ShapeDtypeStruct((), jnp.int32), batch)
        assert lowered.compile() is not None
