"""Event-driven execution subsystem: engine determinism, queueing sanity
(M/D/1 + processor sharing), the schedule registry contract, sync
bit-identity against the legacy round-synchronous arithmetic across the
scenario/topology matrix, the pipelined schedule's strict wall-clock drop
(including the paper config), async/semi-async timeline semantics and
purity, trace-count bounds under every schedule, checkpoint schedule
guards, and the schedules sweep axis."""

import numpy as np
import pytest

from repro.api import Experiment, get_schedule, schedules
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import delay_model as dm
from repro.core import federated
from repro.core import resource_alloc as ra
from repro.des import queueing
from repro.des.engine import EventSim
from repro.des.schedules import (AsyncSchedule, PipelinedSchedule,
                                 SemiAsyncSchedule, SyncSchedule)
from repro.sim import events
from repro.sim.sweep import run_sweep

K = 6
COHORT = 4
ROUNDS = 2


@pytest.fixture(scope="module")
def fcfg():
    return FedsLLMConfig(num_clients=K)


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=K))


@pytest.fixture(scope="module")
def stream(run_cfg):
    from repro.data.tokens import TokenStream

    return TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)


def _fresh(run_cfg, **kw):
    kw.setdefault("allocator", "EB")
    kw.setdefault("eta", 0.5)
    return Experiment.from_config(run_cfg, **kw)


# ---------------------------------------------------------------------------
# Engine: deterministic (time, seq) order
# ---------------------------------------------------------------------------


def test_engine_pops_by_time_then_schedule_order():
    sim = EventSim()
    sim.schedule(2.0, "b")
    sim.schedule(1.0, "a")
    sim.schedule(2.0, "c")  # same time as "b", scheduled later
    trace = sim.run()
    assert [e.kind for e in trace] == ["a", "b", "c"]
    assert sim.now == 2.0 and sim.pending == 0


def test_engine_handler_scheduling_and_stop():
    sim = EventSim()
    sim.schedule(1.0, "tick", n=0)

    def handler(s, ev):
        n = ev.data["n"]
        if n >= 4:
            s.stop()
        else:
            s.after(1.0, "tick", n=n + 1)

    trace = sim.run(handler)
    assert [e.data["n"] for e in trace] == [0, 1, 2, 3, 4]
    assert sim.now == 5.0


def test_engine_rejects_past_and_negative():
    sim = EventSim()
    sim.schedule(1.0, "a")
    sim.run()
    with pytest.raises(ValueError):
        sim.schedule(0.5, "late")
    with pytest.raises(ValueError):
        sim.after(-1.0, "neg")


def test_engine_event_budget():
    sim = EventSim()
    sim.schedule(0.0, "boom")
    with pytest.raises(RuntimeError):
        sim.run(lambda s, e: s.after(0.0, "boom"), max_events=100)


def test_engine_until_leaves_later_events_queued():
    sim = EventSim()
    sim.schedule(1.0, "a")
    sim.schedule(5.0, "b")
    trace = sim.run(until=2.0)
    assert [e.kind for e in trace] == ["a"] and sim.pending == 1


# ---------------------------------------------------------------------------
# Queueing: FIFO vs M/D/1, processor sharing, broadcast
# ---------------------------------------------------------------------------


def test_fifo_serialises_in_arrival_order():
    comp, wait = queueing.fifo(np.array([0.0, 0.0, 1.0]),
                               np.array([2.0, 2.0, 1.0]))
    np.testing.assert_allclose(comp, [2.0, 4.0, 5.0])
    np.testing.assert_allclose(wait, [0.0, 2.0, 3.0])


def test_fifo_matches_md1_mean_wait_at_low_utilisation(fcfg):
    """Simulated FIFO mean wait vs the Pollaczek–Khinchine M/D/1 formula
    (deterministic service) — within 10% at ρ = 0.2 over 40k jobs."""
    lam, service = 0.2, 1.0
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=40_000))
    _, wait = queueing.fifo(arrivals, np.full_like(arrivals, service))
    analytic = queueing.md1_mean_wait(lam, service)
    assert analytic == pytest.approx(0.125)
    assert float(wait.mean()) == pytest.approx(analytic, rel=0.10)


def test_md1_saturates_at_unit_utilisation():
    assert np.isinf(queueing.md1_mean_wait(1.0, 1.0))
    assert queueing.md1_mean_wait(0.0, 1.0) == 0.0


def test_ps_matches_analytic_mean_sojourn_at_low_utilisation():
    """Simulated egalitarian-PS mean sojourn vs the M/G/1-PS formula
    s/(1−ρ) (insensitive to the service distribution, so it holds for our
    deterministic payloads) — within 10% at ρ = 0.2 over 40k jobs.  This
    is the analytic model the wait-aware allocator folds into its budgets
    (``ps_mean_wait`` is the extra-delay part, sojourn − s)."""
    lam, service = 0.2, 1.0
    rng = np.random.default_rng(11)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=40_000))
    comp = queueing.processor_sharing(arrivals,
                                      np.full_like(arrivals, service),
                                      rate=1.0)
    sojourn = comp - arrivals
    analytic = service + queueing.ps_mean_wait(lam, service)
    assert analytic == pytest.approx(1.25)
    assert float(sojourn.mean()) == pytest.approx(analytic, rel=0.10)


def test_ps_mean_wait_saturates_at_unit_utilisation():
    assert np.isinf(queueing.ps_mean_wait(1.0, 1.0))
    assert queueing.ps_mean_wait(0.0, 1.0) == 0.0


def test_processor_sharing_equal_split():
    # two jobs of demand 2 sharing rate 1 from t=0: each sees rate 1/2
    # until a third (demand 1) arrives at t=1 and all share rate 1/3
    comp = queueing.processor_sharing(np.array([0.0, 0.0, 1.0]),
                                      np.array([2.0, 2.0, 1.0]), rate=1.0)
    np.testing.assert_allclose(comp, [5.0, 5.0, 4.0])


def test_processor_sharing_degenerates_to_service_when_alone():
    comp = queueing.processor_sharing(np.array([3.0]), np.array([4.0]),
                                      rate=2.0)
    np.testing.assert_allclose(comp, [5.0])


def test_processor_sharing_stable_at_transfer_scale():
    """The bits-at-Mbps regime that stalls a naive fluid stepper (residues
    below one ulp of the clock) completes and conserves work."""
    rng = np.random.default_rng(0)
    arrivals = 60.0 + rng.uniform(0, 5, 50)
    demands = np.full(50, 28_100.0)
    comp = queueing.processor_sharing(arrivals, demands, rate=2e6)
    assert np.all(np.isfinite(comp))
    assert np.all(comp >= arrivals + demands / 2e6 - 1e-9)


def test_broadcast_seconds():
    assert queueing.broadcast_seconds(1e6, 2e6) == 0.5
    assert queueing.broadcast_seconds(1e6, 0.0) == 0.0  # disabled


def test_queues_handle_infinite_arrivals():
    """An outage'd client's wireless total is +inf — it must never reach
    the queue (completion +inf, no NaN, no server time consumed)."""
    comp, wait = queueing.fifo(np.array([0.0, np.inf, 1.0]),
                               np.array([1.0, 1.0, 1.0]))
    np.testing.assert_allclose(comp, [1.0, np.inf, 2.0])
    assert not np.any(np.isnan(wait)) and wait[1] == np.inf
    ps = queueing.processor_sharing(np.array([0.0, np.inf]),
                                    np.array([2.0, 2.0]), rate=1.0)
    np.testing.assert_allclose(ps, [2.0, np.inf])


def test_queued_backhaul_keeps_outage_clients_infinite(fcfg):
    """Composed path of an outage'd client stays +inf (never NaN) under the
    queueing backhaul — inf−inf must not leak into round wall-clocks."""
    from repro.core import resource_alloc as ra
    from repro.net.topology import EdgeCloudTopology
    from repro.sim.scenario import get_scenario

    net0 = get_scenario("geo-blockfade").initial_network(fcfg, seed=0)
    topo = EdgeCloudTopology(num_edges=2, backhaul_model="fifo",
                             backhaul_bps=2e6)
    net, assign = topo.localize(fcfg, net0)
    alloc = ra.optimize(fcfg, net, strategy="EB")
    import dataclasses

    # force an outage: zero the slowest client's uplink times to +inf
    alloc = dataclasses.replace(
        alloc, t_s=np.where(np.arange(fcfg.num_clients) == 0, np.inf,
                            np.asarray(alloc.t_s, float)))
    t = topo.round_timing(fcfg, net, alloc, 0.5, assign)
    assert not np.any(np.isnan(t.total))
    assert np.isinf(np.asarray(t.total)[0])
    assert np.all(np.isfinite(np.asarray(t.total)[1:]))


# ---------------------------------------------------------------------------
# Registry contract (the sixth axis mirrors the other five)
# ---------------------------------------------------------------------------


def test_schedule_registry_contents():
    assert {"sync", "pipelined", "async", "semi-async"} <= set(schedules.names())


def test_unknown_schedule_lists_known_names():
    with pytest.raises(KeyError) as e:
        get_schedule("nope")
    assert "sync" in str(e.value) and "pipelined" in str(e.value)


def test_unknown_schedule_in_experiment(run_cfg):
    with pytest.raises(KeyError):
        Experiment.from_config(run_cfg, schedule="nope")


def test_get_schedule_accepts_instances():
    inst = PipelinedSchedule(num_microbatches=8)
    assert get_schedule(inst) is inst
    assert get_schedule("semi-async").buffer_k == 4


def test_schedule_parameter_validation():
    with pytest.raises(ValueError):
        PipelinedSchedule(num_microbatches=0)
    with pytest.raises(ValueError):
        AsyncSchedule(beta=-1.0)
    with pytest.raises(ValueError):
        AsyncSchedule(buffer_k=0)


# ---------------------------------------------------------------------------
# sync: bit-identical to the legacy round-synchronous arithmetic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,topology", [
    ("blockfade", "star"),
    ("geo-blockfade", "star"),
    ("geo-blockfade", "edge-cloud"),
    ("drift", "edge-agg"),
])
def test_sync_masks_and_clock_match_legacy(run_cfg, stream, scenario,
                                           topology):
    """Under ``sync`` (the default) every round's straggler mask and
    wall-clock must equal the pre-schedule arithmetic —
    ``events.straggler_mask`` / ``round_wall_clock`` on that round's
    timing — bit-for-bit, on every scenario/topology combination in the
    matrix.  (The absolute star/blockfade trajectory is pinned separately
    by the golden in ``test_topology.py``.)"""
    exp = _fresh(run_cfg, scenario=scenario, topology=topology)
    assert exp.schedule.name == "sync"
    deadline = float(np.quantile(exp.timing.total, 0.7))
    res = exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT,
                  deadline=deadline, resample_channel=True)
    assert exp.trace_count == 1
    for rec in res.records:
        legacy_mask = events.straggler_mask(rec.timing.total, rec.client_ids,
                                            deadline)
        legacy_clock = events.round_wall_clock(rec.timing.total,
                                               rec.client_ids, deadline)
        np.testing.assert_array_equal(rec.mask, legacy_mask)
        assert rec.round_time == legacy_clock
        # the per-event record replays the same completions
        completes = [e for e in rec.events if e["kind"] == "complete"]
        assert len(completes) == rec.cohort_size
        np.testing.assert_array_equal(
            sorted(e["t"] for e in completes),
            np.sort(np.asarray(rec.timing.total)[rec.client_ids]))


def test_round_state_is_pure_and_matches_campaign(run_cfg, stream):
    """``events.round_state`` re-derives exactly the pricing each campaign
    round ran under, from a FRESH experiment — the purity the async
    timeline (and checkpoint resume) is built on."""
    exp = _fresh(run_cfg, scenario="geo-blockfade", topology="edge-cloud")
    res = exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT)
    probe = _fresh(run_cfg, scenario="geo-blockfade", topology="edge-cloud")
    for rec in res.records:
        net, assign, alloc, eta, timing = events.round_state(
            probe, probe.seed, rec.round)
        np.testing.assert_array_equal(timing.total, rec.timing.total)
        np.testing.assert_array_equal(alloc.t_c, rec.alloc.t_c)
        assert eta == rec.eta


# ---------------------------------------------------------------------------
# pipelined: strict wall-clock drop
# ---------------------------------------------------------------------------


def test_pipelined_strictly_faster_on_paper_config():
    """On the paper's §IV configuration (K=50 default cell), microbatch
    overlap strictly reduces EVERY client's simulated round time, for any
    M > 1 — and M=1 degenerates to the sequential eq. (15) total."""
    fcfg = FedsLLMConfig()
    net = dm.sample_network(fcfg, seed=0)
    alloc = ra.optimize(fcfg, net, strategy="EB")
    eta = min(alloc.eta, fcfg.eta_train_max)
    from repro.core import fedsllm

    sync_total = np.asarray(
        fedsllm.simulate_round_time(fcfg, net, alloc, eta).total, float)
    m1 = PipelinedSchedule(num_microbatches=1).pipelined_totals(
        fcfg, net, alloc, eta)
    np.testing.assert_allclose(m1, sync_total, rtol=1e-9)
    for M in (2, 4, 8):
        pipe = PipelinedSchedule(num_microbatches=M).pipelined_totals(
            fcfg, net, alloc, eta)
        assert np.all(pipe < sync_total), (M, np.max(pipe - sync_total))


def test_pipelined_campaign_reduces_simulated_time(run_cfg, stream):
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    res_sync = _fresh(run_cfg).run(num_rounds=ROUNDS, **kw)
    exp = _fresh(run_cfg, schedule="pipelined")
    res_pipe = exp.run(num_rounds=ROUNDS, **kw)
    assert res_pipe.total_time < res_sync.total_time
    assert res_pipe.schedule == "pipelined" and exp.trace_count == 1
    # training semantics untouched when nobody straggles (no deadline)
    np.testing.assert_allclose(res_pipe.history("loss_round_start"),
                               res_sync.history("loss_round_start"),
                               rtol=1e-6)


def test_pipelined_carries_hierarchical_hops(run_cfg):
    """The backhaul hop sits outside the iteration loop: pipelined totals on
    an edge-cloud path include it unchanged (the serial pipe is
    arrival-independent)."""
    exp = _fresh(run_cfg, scenario="geo-blockfade", topology="edge-cloud",
                 schedule="pipelined")
    totals = exp.schedule.completion_times(exp)
    wireless_only = exp.schedule.pipelined_totals(exp.fcfg, exp.net,
                                                  exp.alloc, exp.eta)
    np.testing.assert_allclose(totals - wireless_only,
                               np.asarray(exp.timing.backhaul, float))


def test_pipelined_queued_backhaul_prices_pipelined_arrivals(run_cfg):
    """Under a queueing backhaul the waits depend on arrival times, so the
    pipelined schedule must feed the queue its PIPELINED completions —
    mixing sync-arrival waits into a pipelined timeline would be
    internally inconsistent."""
    from repro.net.topology import EdgeCloudTopology

    topo = EdgeCloudTopology(num_edges=2, backhaul_model="fifo",
                             backhaul_bps=2e6)
    exp = _fresh(run_cfg, scenario="geo-blockfade", schedule="pipelined",
                 topology=topo)
    wireless = exp.schedule.pipelined_totals(exp.fcfg, exp.net, exp.alloc,
                                             exp.eta)
    expected = wireless + topo._queued_backhaul(exp.fcfg, exp.assign,
                                                exp.eta, wireless)
    np.testing.assert_allclose(exp.schedule.completion_times(exp), expected)


# ---------------------------------------------------------------------------
# async / semi-async: timeline semantics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def async_pair(run_cfg, stream):
    """The same async campaign run twice from identical configs."""

    def go():
        exp = _fresh(run_cfg, schedule="async")
        res = exp.run(num_rounds=3, stream=stream)
        return exp, res

    return go(), go()


def test_async_each_round_is_one_arrival(async_pair):
    (exp, res), _ = async_pair
    assert exp.trace_count == 1
    for rec in res.records:
        assert rec.cohort_size == K  # full population through the round fn
        assert int(np.sum(rec.mask > 0)) == 1  # exactly one arrival
        assert rec.round_time >= 0.0
        kinds = [e["kind"] for e in rec.events]
        assert kinds[-1] == "aggregate"


def test_async_staleness_and_discount_wiring(run_cfg, async_pair):
    """The plan's weight scale IS the staleness discount 1/(1+s)^β on the
    arrival slots (and exactly 1 elsewhere, where the mask already zeroes
    the contribution) — the w ∝ D_k/(1+staleness)^β rule, pre-folded for
    the round function's value-only weights argument."""
    exp = _fresh(run_cfg, schedule="async")
    planner = exp.schedule.planner(
        exp, campaign_seed=exp.seed, start=0, target=3, cohort=K,
        fixed_cohort=None, deadline=None, resample_channel=True,
        reallocate=False, realloc_search="warm")
    ids = np.arange(K)
    first = planner.round_plan(0, ids)
    assert np.all(first.staleness[first.mask > 0] == 0)  # fresh arrival
    for r in range(3):
        plan = planner.round_plan(r, ids)
        arr = plan.mask > 0
        assert np.all(plan.staleness >= 0)
        np.testing.assert_allclose(
            plan.weight_scale[arr],
            federated.staleness_discount(plan.staleness[arr], beta=0.5))
        np.testing.assert_array_equal(plan.weight_scale[~arr], 1.0)
    # the recorded staleness survives onto the campaign records too
    (_, res), _ = async_pair
    assert all(rec.staleness is not None for rec in res.records)
    # ...and the server mixing rate α is the arrivals' mean discount —
    # the ABSOLUTE damping (a normalized weighted mean cancels any common
    # per-client discount, so weights alone cannot express FedAsync)
    plan = planner.round_plan(2, ids)
    np.testing.assert_allclose(
        plan.update_scale,
        float(np.mean(plan.weight_scale[plan.mask > 0])))


def test_update_scale_damps_the_aggregated_update(run_cfg, stream):
    """α = 0 must leave the adapters untouched (Δw ← Δw + 0·h̄) and
    α = None must equal α = 1 bit-exactly — the server mixing rate the
    async staleness discount actually acts through."""
    import jax
    from repro.data.tokens import client_batches

    batches = client_batches(stream, 0, K)
    exp_frozen = _fresh(run_cfg)
    before = jax.tree.leaves((exp_frozen.state.lora_c, exp_frozen.state.lora_s))
    before = [np.asarray(x).copy() for x in before]
    exp_frozen.run_round(batches, update_scale=0.0)
    after = jax.tree.leaves((exp_frozen.state.lora_c, exp_frozen.state.lora_s))
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, np.asarray(b))

    res_none = _fresh(run_cfg).run_round(batches)
    res_one = _fresh(run_cfg).run_round(batches, update_scale=1.0)
    for a, b in zip(jax.tree.leaves(res_none.state.lora_c),
                    jax.tree.leaves(res_one.state.lora_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_beta_changes_the_trajectory(run_cfg, stream):
    """The staleness exponent must MATTER under pure async (buffer 1):
    stale arrivals are damped through the server mixing rate, so β=0 and
    β=2 diverge once staleness > 0 (round ≥ 1)."""
    from repro.des.schedules import AsyncSchedule

    res_a = _fresh(run_cfg, schedule=AsyncSchedule(beta=0.0)).run(
        num_rounds=3, stream=stream)
    res_b = _fresh(run_cfg, schedule=AsyncSchedule(beta=2.0)).run(
        num_rounds=3, stream=stream)
    # identical timeline (durations don't depend on β)...
    assert [r.round_time for r in res_a.records] == [
        r.round_time for r in res_b.records]
    # ...but different training trajectories once staleness kicks in
    assert (res_a.records[-1].metrics["loss_round_start"]
            != res_b.records[-1].metrics["loss_round_start"])


def test_async_timeline_pure(async_pair):
    """Two identical async campaigns produce byte-identical timelines,
    masks, staleness and training metrics (the purity property, for the
    schedule with the most internal state)."""
    (_, a), (_, b) = async_pair
    for ra_, rb in zip(a.records, b.records):
        assert ra_.round_time == rb.round_time
        np.testing.assert_array_equal(ra_.mask, rb.mask)
        np.testing.assert_array_equal(ra_.staleness, rb.staleness)
        assert ra_.metrics == rb.metrics
        assert ra_.events == rb.events


def test_semi_async_buffers_distinct_clients(run_cfg, stream):
    exp = _fresh(run_cfg, schedule=SemiAsyncSchedule(buffer_k=3))
    res = exp.run(num_rounds=ROUNDS, stream=stream)
    assert exp.trace_count == 1
    for rec in res.records:
        assert int(np.sum(rec.mask > 0)) == 3  # buffer_k DISTINCT arrivals


def test_semi_async_rejects_buffer_larger_than_population(run_cfg, stream):
    """The client-keyed buffer can hold at most K distinct pending updates;
    buffer_k > K would spin forever, so the planner refuses upfront."""
    exp = _fresh(run_cfg, schedule=SemiAsyncSchedule(buffer_k=K + 1))
    with pytest.raises(ValueError, match="buffer_k"):
        exp.run(num_rounds=1, stream=stream)


def test_pipelined_completions_recorded_and_consistent(run_cfg, stream):
    """RoundRecord.completion carries the schedule-priced per-client times:
    the recorded mask re-derives from THEM (not from ``timing``, which
    keeps the §III sequential pricing)."""
    exp = _fresh(run_cfg, schedule="pipelined")
    deadline = float(np.quantile(exp.schedule.completion_times(exp), 0.7))
    res = exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT,
                  deadline=deadline)
    for rec in res.records:
        assert rec.completion is not None and len(rec.completion) == COHORT
        np.testing.assert_array_equal(
            rec.mask, (rec.completion <= deadline).astype(np.float32))


def test_async_deadline_cancels_and_restarts(run_cfg, stream):
    """A deadline under async cancels over-budget runs (timeout events) but
    the timeline still aggregates — stragglers restart, they don't wedge
    the server.  The deadline sits at the 30th percentile of the ROUND-0
    run durations, so most of the population times out at t=deadline while
    the fast clients keep aggregations flowing past it."""
    probe = _fresh(run_cfg)
    d0 = np.asarray(events.round_state(probe, probe.seed, 0)[4].total, float)
    deadline = float(np.percentile(d0, 30))
    assert np.sum(d0 > deadline) >= 2  # someone actually times out
    exp = _fresh(run_cfg, schedule="async")
    res = exp.run(num_rounds=3, stream=stream, deadline=deadline)
    assert res.num_rounds == 3
    kinds = [e["kind"] for rec in res.records for e in rec.events]
    assert "timeout" in kinds
    # every aggregated arrival met the deadline on its own run
    for rec in res.records:
        assert int(np.sum(rec.mask > 0)) == 1


def test_async_impossible_deadline_raises(run_cfg, stream):
    exp = _fresh(run_cfg, schedule="async")
    with pytest.raises(RuntimeError):
        exp.run(num_rounds=1, stream=stream, deadline=1e-6)


def test_async_rejects_mismatched_fixed_batches(run_cfg, stream):
    from repro.data.tokens import client_batches

    exp = _fresh(run_cfg, schedule="async")
    batches = client_batches(stream, 0, COHORT)  # leading axis 4 != K
    with pytest.raises(ValueError):
        exp.run(num_rounds=1, stream=None, batches=batches)


# ---------------------------------------------------------------------------
# Purity + trace bounds for EVERY registered schedule (the satellite
# property test: pure in (seed, round), one jit trace at fixed η)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(["sync", "pipelined", "async",
                                         "semi-async"]))
def test_schedule_pure_in_seed_and_round(run_cfg, stream, name):
    def go():
        exp = _fresh(run_cfg, schedule=name)
        res = exp.run(num_rounds=ROUNDS, stream=stream,
                      cohort=(K if name in ("async", "semi-async")
                              else COHORT))
        return exp, res

    (exp_a, a), (exp_b, b) = go(), go()
    assert exp_a.trace_count == 1 and exp_b.trace_count == 1
    assert a.schedule == name
    for ra_, rb in zip(a.records, b.records):
        assert ra_.round_time == rb.round_time
        assert ra_.metrics == rb.metrics
        np.testing.assert_array_equal(ra_.client_ids, rb.client_ids)
        if ra_.mask is None:
            assert rb.mask is None
        else:
            np.testing.assert_array_equal(ra_.mask, rb.mask)


# ---------------------------------------------------------------------------
# Checkpoints: the schedule is campaign identity
# ---------------------------------------------------------------------------


def test_resume_refuses_different_schedule(run_cfg, stream, tmp_path):
    d = str(tmp_path / "ckpt")
    exp = _fresh(run_cfg, schedule="pipelined")
    exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT,
            checkpoint_dir=d)
    other = _fresh(run_cfg)  # sync
    with pytest.raises(ValueError, match="schedule"):
        other.run(num_rounds=ROUNDS + 1, stream=stream, cohort=COHORT,
                  checkpoint_dir=d, resume=True)


def test_resume_refuses_different_schedule_params(run_cfg, stream, tmp_path):
    """Like scenario/topology digests, the schedule's PARAMS are campaign
    identity: a different microbatch count (or β, buffer_k) re-times the
    whole timeline, so resuming under it must be refused."""
    d = str(tmp_path / "ckpt")
    exp = _fresh(run_cfg, schedule=PipelinedSchedule(num_microbatches=4))
    exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT,
            checkpoint_dir=d)
    other = _fresh(run_cfg, schedule=PipelinedSchedule(num_microbatches=8))
    with pytest.raises(ValueError, match="schedule_params"):
        other.run(num_rounds=ROUNDS + 1, stream=stream, cohort=COHORT,
                  checkpoint_dir=d, resume=True)


def test_async_resume_is_bit_identical(run_cfg, stream, tmp_path):
    """Resume replays the async timeline exactly: the interrupted campaign's
    remaining rounds equal the uninterrupted one's (the re-run-from-zero
    timeline idiom)."""
    d = str(tmp_path / "ckpt")
    full = _fresh(run_cfg, schedule="async").run(num_rounds=3, stream=stream)
    exp = _fresh(run_cfg, schedule="async")
    exp.run(num_rounds=2, stream=stream, checkpoint_dir=d)
    resumed_exp = _fresh(run_cfg, schedule="async")
    resumed = resumed_exp.run(num_rounds=3, stream=stream, checkpoint_dir=d,
                              resume=True)
    assert [r.round for r in resumed.records] == [2]
    tail = full.records[2]
    got = resumed.records[0]
    assert got.round_time == tail.round_time
    np.testing.assert_array_equal(got.mask, tail.mask)
    np.testing.assert_array_equal(got.staleness, tail.staleness)
    assert got.metrics == tail.metrics
    assert resumed.total_time == full.total_time


# ---------------------------------------------------------------------------
# Sweep: the schedules axis
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sched_sweep(run_cfg, stream):
    return run_sweep(run_cfg, ROUNDS, topologies=("star",),
                     scenarios=("geo-blockfade",), allocators=("EB",),
                     schedules=("sync", "pipelined"), stream=stream,
                     cohort=COHORT, exp_overrides={"cut": 1, "eta": 0.5})


def test_sweep_schedule_rows_and_meta(sched_sweep):
    assert len(sched_sweep.records) == 2 * ROUNDS
    assert {r["schedule"] for r in sched_sweep.records} == {"sync",
                                                            "pipelined"}
    for row in sched_sweep.summary():
        assert row["schedule"] in ("sync", "pipelined")
        assert row["trace_count"] == 1
    with pytest.raises(ValueError):
        sched_sweep.cell("geo-blockfade", "EB")  # ambiguous schedule


def test_sweep_schedule_speedup(sched_sweep):
    speedup = sched_sweep.schedule_speedup()
    assert set(speedup) == {"star/geo-blockfade/EB/pipelined"}
    assert 0 < speedup["star/geo-blockfade/EB/pipelined"] < 100


def test_sweep_json_records_schedules(sched_sweep, tmp_path):
    import json

    with open(sched_sweep.to_json(str(tmp_path / "s.json"))) as f:
        payload = json.load(f)
    assert payload["schedules"] == ["sync", "pipelined"]
    assert payload["schedule_speedup_pct"]


# ---------------------------------------------------------------------------
# The staleness-weighted aggregator (core/federated)
# ---------------------------------------------------------------------------


def test_staleness_weighted_equals_discounted_fedavg():
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32))}
    D = jnp.asarray(rng.uniform(1, 2, 5).astype(np.float32))
    s = jnp.asarray([0.0, 1.0, 4.0, 0.0, 2.0])
    out = federated.staleness_weighted(tree, weights=D, staleness=s, beta=0.5)
    ref = federated.fedavg(tree, weights=D * (1.0 + s) ** -0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               rtol=1e-6)


def test_staleness_weighted_is_mask_aware():
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    clean = rng.normal(size=(4, 3)).astype(np.float32)
    poisoned = clean.copy()
    poisoned[2] = 1e6
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    s = jnp.asarray([0.0, 3.0, 0.0, 1.0])
    a = federated.staleness_weighted({"w": jnp.asarray(clean)}, mask=mask,
                                     staleness=s)
    b = federated.staleness_weighted({"w": jnp.asarray(poisoned)}, mask=mask,
                                     staleness=s)
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
