"""Local-update algorithm registry (7th axis) + data-heterogeneity
workloads: registry contracts, the gd bit-compat golden, FedProx/SCAFFOLD
semantics (μ=0 degeneracy, variate updates, straggler mask-invariance),
single-jit-trace bounds, scaffold checkpoint/resume identity, workload
purity in (seed, client), and the local-algo sweep dimension."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Experiment, get_local_algo, get_workload, local_algos,
                       workloads)
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import delay_model as dm
from repro.core import fedsllm
from repro.data.tokens import TokenStream
from repro.fl.local_algos import FedProxLocal, GDLocal, ScaffoldLocal
from repro.fl.workloads import (DirichletDomainWorkload, IIDWorkload,
                                LengthSkewWorkload, QuantitySkewWorkload)
from repro.sim.campaign import stream_batcher
from repro.sim.sweep import run_sweep

K = 6
COHORT = 4


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=K))


@pytest.fixture(scope="module")
def stream(run_cfg):
    return TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)


def _fresh(run_cfg, **kw):
    kw.setdefault("allocator", "EB")
    kw.setdefault("eta", 0.5)
    return Experiment.from_config(run_cfg, **kw)


def _campaign(exp, stream, rounds=3):
    deadline = float(np.quantile(exp.timing.total, 0.7))
    return exp.run(num_rounds=rounds, stream=stream, cohort=COHORT,
                   deadline=deadline, resample_channel=True)


@pytest.fixture(scope="module")
def gd_run(run_cfg, stream):
    exp = _fresh(run_cfg)
    return exp, _campaign(exp, stream)


@pytest.fixture(scope="module")
def scaffold_run(run_cfg, stream):
    exp = _fresh(run_cfg, local_algo="scaffold")
    return exp, _campaign(exp, stream)


def _lora_leaves(state):
    return jax.tree.leaves((state.lora_c, state.lora_s))


# ---------------------------------------------------------------------------
# Registry contract (the seventh axis mirrors the other six)
# ---------------------------------------------------------------------------


def test_local_algo_registry_contents():
    assert {"gd", "fedprox", "scaffold"} <= set(local_algos.names())


def test_workload_registry_contents():
    assert {"iid", "quantity-skew", "length-skew",
            "dirichlet"} <= set(workloads.names())


def test_unknown_names_list_known_names():
    with pytest.raises(KeyError) as exc:
        get_local_algo("definitely-not-registered")
    for name in local_algos.names():
        assert name in str(exc.value)
    with pytest.raises(KeyError) as exc:
        get_workload("definitely-not-registered")
    for name in workloads.names():
        assert name in str(exc.value)


def test_unknown_axes_in_experiment(run_cfg):
    with pytest.raises(KeyError, match="unknown local_algo"):
        Experiment.from_config(run_cfg, local_algo="nope")
    with pytest.raises(KeyError, match="unknown workload"):
        Experiment.from_config(run_cfg, workload="nope")


def test_getters_accept_instances_and_kwargs():
    prox = FedProxLocal(mu=0.3)
    assert get_local_algo(prox) is prox
    assert get_local_algo("fedprox", mu=0.7).mu == 0.7
    assert isinstance(get_local_algo(ScaffoldLocal), ScaffoldLocal)
    wl = QuantitySkewWorkload(alpha=0.1)
    assert get_workload(wl) is wl
    assert get_workload("dirichlet", alpha=0.2).alpha == 0.2
    with pytest.raises(TypeError):
        get_local_algo(prox, mu=0.5)


def test_params_feed_checkpoint_identity():
    assert GDLocal().params() == {}
    assert FedProxLocal(mu=0.25).params() == {"mu": 0.25}
    assert IIDWorkload().params() == {}
    assert "alpha" in DirichletDomainWorkload().params()


# ---------------------------------------------------------------------------
# Lemma 2 dedupe (satellite): fedsllm delegates to delay_model
# ---------------------------------------------------------------------------


def test_local_iteration_count_consistent_with_delay_model():
    import math
    for fcfg in (FedsLLMConfig(), FedsLLMConfig(num_clients=K, L_smooth=1.5)):
        for eta in (0.05, 0.1, 0.3, 0.5, 0.7, 0.9, 0.95):
            got = fedsllm.local_iteration_count(fcfg, eta)
            assert got == max(1, math.ceil(dm.local_iters(fcfg, eta)))
            # the pre-dedupe closed form, for the avoidance of drift
            assert got == max(1, math.ceil(dm.lemma_v(fcfg)
                                           * math.log2(1.0 / eta)))


# ---------------------------------------------------------------------------
# gd bit-compat golden (same capture as tests/test_topology.py: smoke
# fedsllm-100m, K=6, EB, eta=0.5, cohort 4, 0.7-quantile deadline, 3 rounds)
# ---------------------------------------------------------------------------

GOLDEN_DEADLINE = 110.61189496631023
GOLDEN_LOSSES = (5.556713104248047, 5.560213088989258, 5.551358222961426)
GOLDEN_ROUND_TIMES = (110.61189496631023, 110.61189496631023,
                      104.78746742360255)
GOLDEN_TOTAL_TIME = 326.01125735622304


def test_gd_campaign_matches_pre_registry_golden(gd_run):
    """The default local algorithm IS the legacy inner loop — the pre-PR
    star/blockfade trajectory reproduces exactly."""
    exp, res = gd_run
    assert exp.local_algo.name == "gd" and exp.workload.name == "iid"
    assert exp.algo_state is None
    np.testing.assert_allclose([r.round_time for r in res.records],
                               GOLDEN_ROUND_TIMES, rtol=1e-12)
    np.testing.assert_allclose(res.total_time, GOLDEN_TOTAL_TIME, rtol=1e-12)
    np.testing.assert_allclose(res.history("loss_round_start"),
                               GOLDEN_LOSSES, rtol=1e-5)
    assert exp.trace_count == 1


def test_fedprox_mu0_is_gd_bit_exact(run_cfg, stream, gd_run):
    """μ = 0 removes the proximal pull: the trajectory must be bit-identical
    to gd (x + 0·h == x in IEEE arithmetic)."""
    exp = _fresh(run_cfg, local_algo=FedProxLocal(mu=0.0))
    res = _campaign(exp, stream)
    for a, b in zip(_lora_leaves(res.state), _lora_leaves(gd_run[1].state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scaffold_round0_equals_gd(scaffold_run, gd_run):
    """Variates start at zero, so scaffold's first round is gd's first round
    exactly; corrections only alter the trajectory from round 1 on."""
    _, s_res = scaffold_run
    _, g_res = gd_run
    for k, v in s_res.records[0].metrics.items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(g_res.records[0].metrics[k]))
    np.testing.assert_array_equal(
        np.asarray(s_res.records[1].metrics["loss_round_start"]),
        np.asarray(g_res.records[1].metrics["loss_round_start"]))


def test_scaffold_single_trace_and_variate_shape(scaffold_run):
    exp, _ = scaffold_run
    assert exp.trace_count == 1
    leaves = jax.tree.leaves(exp.algo_state)
    assert all(x.shape[0] == K for x in leaves)
    # three rounds of cohort-4 participation left *some* variate nonzero
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in leaves)


def test_fedprox_single_trace(run_cfg, stream):
    exp = _fresh(run_cfg, local_algo="fedprox")
    _campaign(exp, stream)
    assert exp.trace_count == 1 and exp.algo_state is None


# ---------------------------------------------------------------------------
# SCAFFOLD variate semantics
# ---------------------------------------------------------------------------


def _round_batches(stream, ids):
    per = [stream.batch_at(int(k)) for k in ids]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per)


def test_scaffold_mask_invariance_of_variates(run_cfg, stream):
    """Dropped clients' control variates must not update: a straggler that
    missed the round learned nothing, and clients outside the cohort were
    never asked."""
    exp = _fresh(run_cfg, local_algo="scaffold")
    ids = np.array([0, 1, 2, 3])
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    exp.run_round(_round_batches(stream, ids), mask=mask, client_ids=ids)
    rows = {k: [np.asarray(x[k]) for x in jax.tree.leaves(exp.algo_state)]
            for k in range(K)}
    for k in (0, 1, 3):  # participated and survived: variates moved off 0
        assert any(np.max(np.abs(r)) > 0 for r in rows[k])
    for k in (2, 4, 5):  # masked straggler + out-of-cohort: untouched
        for r in rows[k]:
            np.testing.assert_array_equal(r, np.zeros_like(r))
    # a second round with the roles flipped updates exactly the newcomers
    before = [np.asarray(x) for x in jax.tree.leaves(exp.algo_state)]
    exp.run_round(_round_batches(stream, ids),
                  mask=jnp.asarray([0.0, 1.0, 1.0, 1.0]), client_ids=ids)
    after = [np.asarray(x) for x in jax.tree.leaves(exp.algo_state)]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b[0], a[0])  # masked this time: frozen
    assert any(np.max(np.abs(x[2])) > 0 for x in after)  # client 2 now moved
    assert exp.trace_count == 1  # masks and ids are value-only


def test_scaffold_option2_update_rule():
    """c_k⁺ = c_k − c̄ − h/(I_loc·δ), with the mask blending old and new."""
    algo = ScaffoldLocal()
    ctrl = ({"w": jnp.asarray([[1.0], [2.0]])},)
    cbar = ({"w": jnp.asarray([0.5])},)
    h = ({"w": jnp.asarray([[4.0], [8.0]])},)
    upd = algo.update_variates(ctrl, cbar, h, None, I_loc=4, delta=0.5)
    np.testing.assert_allclose(np.asarray(upd[0]["w"]),
                               [[1.0 - 0.5 - 2.0], [2.0 - 0.5 - 4.0]])
    masked = algo.update_variates(ctrl, cbar, h, jnp.asarray([1.0, 0.0]),
                                  I_loc=4, delta=0.5)
    np.testing.assert_allclose(np.asarray(masked[0]["w"]), [[-1.5], [2.0]])


def test_scaffold_checkpoint_resume_bit_identical(run_cfg, stream, tmp_path):
    """The acceptance bar: an interrupted scaffold campaign resumes with the
    exact variates and replays the remaining rounds bit-identically."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    mk = lambda: _fresh(run_cfg, local_algo="scaffold")  # noqa: E731

    full = mk()
    res_full = full.run(num_rounds=4, **kw)

    ck = str(tmp_path / "scaffold_ck")
    part = mk()
    part.run(num_rounds=2, checkpoint_dir=ck, checkpoint_every=2, **kw)
    resumed = mk()
    res_res = resumed.run(num_rounds=4, checkpoint_dir=ck, resume=True, **kw)

    for a, b in zip(_lora_leaves(res_full.state), _lora_leaves(res_res.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(full.algo_state),
                    jax.tree.leaves(resumed.algo_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [r.round for r in res_res.records] == [2, 3]
    np.testing.assert_allclose(res_res.total_time, res_full.total_time,
                               rtol=1e-12)

    # a different local algorithm refuses the checkpoint, like a different
    # schedule or scenario would
    with pytest.raises(ValueError, match="different campaign"):
        _fresh(run_cfg).run(num_rounds=4, checkpoint_dir=ck, resume=True, **kw)
    # ... and so do different hyper-parameters of the same algorithm
    with pytest.raises(ValueError, match="different campaign"):
        _fresh(run_cfg, local_algo=FedProxLocal(mu=0.0)).run(
            num_rounds=4, checkpoint_dir=ck, resume=True, **kw)


# ---------------------------------------------------------------------------
# Workloads: purity in (seed, client), iid bit-compat, skew semantics
# ---------------------------------------------------------------------------


def test_iid_workload_matches_legacy_stream_batcher(stream):
    legacy = stream_batcher(stream, K)
    wl = IIDWorkload().batcher(stream, K)
    ids = np.array([0, 3, 5])
    for r in (0, 2):
        for a, b in zip(jax.tree.leaves(legacy(r, ids)),
                        jax.tree.leaves(wl(r, ids))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name,kw", [
    ("iid", {}),
    ("quantity-skew", {}),
    ("length-skew", {}),
    ("dirichlet", {"domain_pool": 8}),
])
def test_workload_pure_in_seed_and_client(stream, name, kw):
    """Client k's round-r batch never depends on who else was sampled, and
    rebuilding the batcher from the same (stream, K) replays it exactly."""
    wl = get_workload(name, **kw)
    fn_a = wl.batcher(stream, K)
    fn_b = get_workload(name, **kw).batcher(stream, K)
    full = np.arange(K)
    sub = np.array([1, 4])
    for r in (0, 3):
        batch_full = fn_a(r, full)
        batch_sub = fn_a(r, sub)
        for i, k in enumerate(sub):
            for a, b in zip(jax.tree.leaves(batch_sub),
                            jax.tree.leaves(batch_full)):
                np.testing.assert_array_equal(np.asarray(a[i]),
                                              np.asarray(b[k]))
        for a, b in zip(jax.tree.leaves(batch_full),
                        jax.tree.leaves(fn_b(r, full))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantity_skew_pools_cycle(stream):
    wl = QuantitySkewWorkload(alpha=0.3, pool_rounds=4)
    sizes = wl.pool_sizes(stream.seed, K)
    assert sizes.min() >= 1 and len(sizes) == K
    fn = wl.batcher(stream, K)
    k = int(np.argmin(sizes))
    n = int(sizes[k])
    a = fn(0, np.array([k]))
    b = fn(n, np.array([k]))  # one full cycle later: same batch again
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # skewed draws give unequal pools on this seed
    assert sizes.max() > sizes.min()


def test_length_skew_truncates_loss_mask(stream):
    wl = LengthSkewWorkload(min_frac=0.25)
    fn = wl.batcher(stream, K)
    iid = IIDWorkload().batcher(stream, K)
    ids = np.arange(K)
    got, ref = fn(1, ids), iid(1, ids)
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(ref["tokens"]))
    fracs = wl.length_fracs(stream.seed, K)
    lengths = np.maximum(1, np.ceil(fracs * stream.seq)).astype(int)
    mask = np.asarray(got["mask"])
    for k in range(K):
        assert (mask[k].sum(axis=-1) == lengths[k]).all()
    assert len(set(lengths.tolist())) > 1  # genuinely heterogeneous


def test_dirichlet_workload_partitions_domains(stream):
    wl = DirichletDomainWorkload(alpha=0.3, num_domains=4, domain_pool=8)
    shards = wl.client_shards(stream.seed, K)
    allidx = np.sort(np.concatenate(shards))
    np.testing.assert_array_equal(allidx, np.arange(4 * 8))
    assert min(len(s) for s in shards) >= 1
    streams = wl.domain_streams(stream)
    assert len({s.seed for s in streams}) == 4
    assert len({s.structure for s in streams}) == 4
    # different stream seeds give different partitions (purity in seed)
    other = wl.client_shards(stream.seed + 1, K)
    assert any(not np.array_equal(a, b) for a, b in zip(shards, other))


def test_non_iid_workload_requires_stream(run_cfg, stream):
    exp = _fresh(run_cfg, workload="dirichlet")
    fixed = _round_batches(stream, np.arange(COHORT))
    with pytest.raises(ValueError, match="workload"):
        exp.run(num_rounds=1, batches=fixed)


def test_describe_names_the_new_axes(run_cfg):
    exp = _fresh(run_cfg, local_algo="fedprox", workload="length-skew")
    assert "algo=fedprox" in exp.describe()
    assert "workload=length-skew" in exp.describe()


# ---------------------------------------------------------------------------
# Sweep dimension
# ---------------------------------------------------------------------------


def test_sweep_local_algo_axis(run_cfg, stream):
    res = run_sweep(run_cfg, 2, scenarios=("blockfade",), allocators=("EB",),
                    local_algos=("gd", "fedprox"), stream=stream,
                    cohort=COHORT, exp_overrides={"eta": 0.5})
    assert {r["local_algo"] for r in res.records} == {"gd", "fedprox"}
    assert all(r["workload"] == "iid" for r in res.records)
    rows = res.cell("blockfade", "EB", local_algo="fedprox")
    assert [r["round"] for r in rows] == [0, 1]
    with pytest.raises(ValueError, match="local_algo"):
        res.cell("blockfade", "EB")
    gain = res.local_algo_gain()
    assert set(gain) == {"blockfade/iid/fedprox"}
    assert len(res.summary()) == 2
    for row in res.summary():
        assert row["trace_count"] == 1


def test_sweep_non_iid_without_stream_raises(run_cfg):
    with pytest.raises(ValueError, match="non-iid"):
        run_sweep(run_cfg, 1, workloads=("dirichlet",), batches={})
