"""Fused chunked CE exactness + ring-buffer position bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import get_arch, smoke_variant
from repro.models import layers as L
from repro.models import transformer as T

settings.register_profile("ci2", deadline=None, max_examples=20)
settings.load_profile("ci2")


@pytest.mark.parametrize("arch,chunk", [
    ("fedsllm-100m", 16), ("gemma2-9b", 8), ("command-r-35b", 32),
    ("phi4-mini-3.8b", 7),  # chunk not dividing S -> padding path
])
def test_fused_ce_matches_reference(arch, chunk):
    cfg = smoke_variant(get_arch(arch))
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    B, S = 2, 24
    kt, kl = jax.random.split(jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
             "mask": jnp.ones((B, S), jnp.float32)}
    logits, _ = T.forward(params, batch, cfg)
    ref = L.cross_entropy(logits, batch["labels"], batch["mask"])
    x, _ = T.hidden_states(params, batch, cfg)
    fused = L.fused_cross_entropy(params["embed"], x, batch["labels"], cfg,
                                  mask=batch["mask"], chunk=chunk)
    np.testing.assert_allclose(float(fused), float(ref), rtol=1e-5, atol=1e-5)


def test_fused_ce_grads_match_reference():
    cfg = smoke_variant(get_arch("fedsllm-100m"))
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    B, S = 2, 16
    kt, kl = jax.random.split(jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
             "mask": jnp.ones((B, S), jnp.float32)}

    def loss_ref(p):
        logits, _ = T.forward(p, batch, cfg)
        return L.cross_entropy(logits, batch["labels"], batch["mask"])

    def loss_fused(p):
        x, _ = T.hidden_states(p, batch, cfg)
        return L.fused_cross_entropy(p["embed"], x, batch["labels"], cfg,
                                     mask=batch["mask"], chunk=8)

    g1 = jax.grad(loss_ref)(params)
    g2 = jax.grad(loss_fused)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_fused_ce_respects_mask():
    cfg = smoke_variant(get_arch("fedsllm-100m"))
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    labels = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    m1 = jnp.ones((B, S), jnp.float32)
    m2 = m1.at[:, : S // 2].set(0.0)
    l_full = L.fused_cross_entropy(params["embed"], x, labels, cfg, mask=m1, chunk=8)
    l_half = L.fused_cross_entropy(params["embed"], x, labels, cfg, mask=m2, chunk=8)
    assert not np.isclose(float(l_full), float(l_half))


@given(st.integers(0, 200), st.sampled_from([4, 8, 16]))
def test_ring_positions_invariants(pos, window):
    """Slot pos%window holds `pos`; all slots hold the largest position
    ≤ pos congruent to the slot index."""
    slots = np.asarray(L._ring_positions(jnp.asarray(pos), window))
    assert slots[pos % window] == pos
    for j, p in enumerate(slots):
        assert p % window == j
        assert p <= pos
        assert p > pos - window
