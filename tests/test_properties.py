"""Hypothesis property tests on system invariants beyond FedAvg."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import resource_alloc as ra

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


@given(st.floats(0.01, 0.95), st.floats(0.01, 0.95))
def test_latency_monotone_in_budget(eta1, eta2):
    """The exact solver's T(η) is a well-defined function: same η -> same T."""
    cfg = FedsLLMConfig(num_clients=4)
    net = dm.sample_network(cfg, seed=0)
    a1 = ra.solve_fixed_eta_exact(cfg, net, eta1)
    a1b = ra.solve_fixed_eta_exact(cfg, net, eta1)
    np.testing.assert_allclose(a1.T, a1b.T, rtol=1e-6)


@given(st.integers(0, 50))
def test_more_power_never_hurts(seed):
    """T* is non-increasing in transmission power (paper Fig. 2 x-axis)."""
    cfg = FedsLLMConfig(num_clients=4)
    net_lo = dm.sample_network(cfg, seed=seed, p_max_dbm=0.0)
    net_hi = dm.sample_network(cfg, seed=seed, p_max_dbm=20.0)
    a_lo = ra.solve_fixed_eta_exact(cfg, net_lo, 0.1)
    a_hi = ra.solve_fixed_eta_exact(cfg, net_hi, 0.1)
    assert a_hi.T <= a_lo.T * 1.001


@given(st.integers(0, 20))
def test_bandwidth_budget_binds_at_optimum(seed):
    """At the minimal T at least one bandwidth budget must bind — otherwise
    T could still be reduced (complementary slackness of the min-max)."""
    cfg = FedsLLMConfig(num_clients=6)
    net = dm.sample_network(cfg, seed=seed)
    a = ra.solve_fixed_eta_exact(cfg, net, 0.1)
    if not a.feasible:
        return
    usage = max(a.b_c.sum() / net.B_c, a.b_s.sum() / net.B_s)
    assert 0.9 <= usage <= 1.0 + 1e-6, usage


@given(st.floats(0.05, 0.9), st.floats(1.2, 3.0))
def test_lemma1_rounds_scale(eta, factor):
    """I0 scales as 1/(1-η) exactly."""
    cfg = FedsLLMConfig()
    I1 = dm.global_rounds(cfg, eta)
    eta2 = 1 - (1 - eta) / factor
    I2 = dm.global_rounds(cfg, eta2)
    np.testing.assert_allclose(I2 / I1, factor, rtol=1e-9)


@given(st.integers(1, 6), st.integers(8, 64))
def test_ssd_chunk_invariance(nheads, seq):
    """Chunked SSD result is independent of chunk size (associativity)."""
    from repro.models.mamba2 import ssd_chunked

    seq = (seq // 8) * 8
    B, H, P, N = 1, nheads, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, seq, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, seq, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, seq, N)) * 0.4
    Cm = jax.random.normal(ks[4], (B, seq, N)) * 0.4
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y2, s2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=seq)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


@given(st.integers(0, 30))
def test_compression_preserves_sum_with_feedback(seed):
    """Across two rounds, error feedback re-injects dropped mass."""
    from repro.core import compression

    rng = np.random.default_rng(seed)
    g1 = {"w": jnp.asarray(rng.normal(size=100), jnp.float32)}
    s1, e1, _ = compression.compress_tree(g1, 0.2)
    g2 = {"w": jnp.asarray(rng.normal(size=100), jnp.float32)}
    s2, e2, _ = compression.compress_tree(g2, 0.2, error=e1)
    total_sent = np.asarray(s1["w"] + s2["w"])
    total_true = np.asarray(g1["w"] + g2["w"])
    # residual bounded by the remaining error memory
    np.testing.assert_allclose(total_sent + np.asarray(e2["w"]), total_true, rtol=1e-5)
