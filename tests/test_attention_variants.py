"""Attention-variant equivalences: banded == full masked sliding window;
chunked-q == full; RG-LRU associative scan == sequential recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L

settings.register_profile("ci3", deadline=None, max_examples=10)
settings.load_profile("ci3")


def _qkv(B, S, H, Kv, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, Kv, d))
    v = jax.random.normal(ks[2], (B, S, Kv, d))
    return q, k, v


@pytest.mark.parametrize("S,window", [(64, 16), (128, 32), (96, 32)])
def test_banded_equals_full_sliding_window(S, window):
    """Block-banded local attention must equal the masked full computation
    (exact for causal window ≤ block size)."""
    B, H, Kv, d = 2, 4, 2, 16
    q, k, v = _qkv(B, S, H, Kv, d)
    full = L._attend_full(q, k, v, causal=True, window=window, softcap=0.0)
    banded = L._attend_banded(q, k, v, window=window, softcap=0.0)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_banded_with_softcap():
    B, S, H, Kv, d, window = 1, 64, 2, 1, 16, 16
    q, k, v = _qkv(B, S, H, Kv, d, seed=3)
    full = L._attend_full(q, k, v, causal=True, window=window, softcap=30.0)
    banded = L._attend_banded(q, k, v, window=window, softcap=30.0)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [16, 32])
def test_chunked_q_equals_full(chunk):
    B, S, H, Kv, d = 1, 64, 2, 2, 16
    q, k, v = _qkv(B, S, H, Kv, d, seed=1)
    full = L._attend_full(q, k, v, causal=True, window=0, softcap=0.0)
    chunked = L._attend_chunked_q(q, k, v, causal=True, window=0, softcap=0.0,
                                  chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(0, 50), st.integers(8, 48))
def test_rglru_scan_equals_sequential(seed, S):
    """Associative scan == step-by-step recurrence h_t = a_t h_{t-1} + b_t."""
    from repro.models.rglru import _linear_scan

    rng = np.random.default_rng(seed)
    B, W = 2, 8
    a = jnp.asarray(rng.uniform(0.5, 0.999, (B, S, W)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.5, (B, S, W)), jnp.float32)
    h0 = jnp.asarray(rng.normal(0, 1, (B, W)), jnp.float32)
    ys = _linear_scan(a, b, h0)
    # sequential reference
    h = np.asarray(h0)
    ref = []
    an, bn = np.asarray(a), np.asarray(b)
    for t in range(S):
        h = an[:, t] * h + bn[:, t]
        ref.append(h.copy())
    ref = np.stack(ref, axis=1)
    np.testing.assert_allclose(np.asarray(ys), ref, rtol=2e-4, atol=2e-5)


def test_decode_attend_ring_vs_linear_cache():
    """Ring-buffer decode for a window layer == linear cache decode with the
    same window mask (positions beyond the window masked identically)."""
    from repro.config import get_arch, smoke_variant

    cfg = smoke_variant(get_arch("recurrentgemma-9b"))
    window = cfg.sliding_window  # 32 in smoke
    B, Kv, hd = 1, 1, cfg.head_dim
    H = cfg.num_heads
    S_hist = window + 7  # history longer than the window
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k_hist = jax.random.normal(ks[1], (B, S_hist, Kv, hd))
    v_hist = jax.random.normal(ks[2], (B, S_hist, Kv, hd))
    pos = S_hist - 1  # decoding the last position; k/v already contain it

    # linear cache: full history with window mask
    out_lin = L._decode_attend(q, k_hist, v_hist, cfg=cfg, window=window,
                               cache_pos=jnp.asarray(pos), kpos_abs=None)
    # ring cache: slot j holds position p ≤ pos with p % window == j
    slots = np.asarray(L._ring_positions(jnp.asarray(pos), window))
    ck = jnp.stack([k_hist[:, p] for p in slots], axis=1)
    cv = jnp.stack([v_hist[:, p] for p in slots], axis=1)
    out_ring = L._decode_attend(q, ck, cv, cfg=cfg, window=window,
                                cache_pos=jnp.asarray(pos),
                                kpos_abs=jnp.asarray(slots))
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(out_lin),
                               rtol=2e-5, atol=2e-5)
