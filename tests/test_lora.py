"""LoRA: eq. (1) semantics, merge equivalence, zero-init, counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LoRAConfig, get_arch, smoke_variant
from repro.core import lora as lora_lib
from repro.models import transformer as T
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def ctx():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(lora=LoRAConfig(rank=4, alpha=8.0))
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(0))
    lora, laxes = lora_lib.init_lora(params, axes, cfg, key=jax.random.PRNGKey(1))
    return cfg, params, axes, lora


def test_lora_zero_init_is_identity(ctx):
    """B = 0 at init -> merged model == base model (paper: Δw = 0)."""
    cfg, params, axes, lora = ctx
    merged = lora_lib.merge(params, lora, cfg)
    m = build_model(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks, "mask": jnp.ones((2, 16), jnp.float32)}
    l1, _ = m.forward(params, batch)
    l2, _ = m.forward(merged, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_merge_matches_factor_product(ctx):
    cfg, params, axes, lora = ctx
    key = next(iter(lora))
    ab = lora[key]
    A = ab["A"] + 0.1
    B = ab["B"] + 0.2
    lora2 = dict(lora)
    lora2[key] = {"A": A, "B": B}
    merged = lora_lib.merge(params, lora2, cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        if jax.tree_util.keystr(path) == key:
            expected = leaf.astype(jnp.float32) + cfg.lora.scale * jnp.einsum(
                "...ir,...ro->...io", A.astype(jnp.float32), B.astype(jnp.float32))
            got = [l for p, l in jax.tree_util.tree_flatten_with_path(merged)[0]
                   if jax.tree_util.keystr(p) == key][0]
            np.testing.assert_allclose(np.asarray(got), np.asarray(expected), rtol=1e-5)
            return
    raise AssertionError("target leaf not found")


def test_rank_bound(ctx):
    """r << min(d, k): every adapter factor respects the configured rank."""
    cfg, params, axes, lora = ctx
    for ab in lora.values():
        assert ab["A"].shape[-1] == cfg.lora.rank
        assert ab["B"].shape[-2] == cfg.lora.rank
        assert cfg.lora.rank < min(ab["A"].shape[-2], ab["B"].shape[-1])


def test_param_count_matches_tree(ctx):
    cfg, params, axes, lora = ctx
    analytic = lora_lib.lora_param_count(cfg)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(lora))
    assert analytic == actual


def test_lora_trainable_fraction():
    """LoRA must be a small fraction of the full model (the paper's point)."""
    cfg = get_arch("fedsllm-100m")
    frac = lora_lib.lora_param_count(cfg) / cfg.param_count()
    assert frac < 0.05, frac


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "mamba2-130m", "recurrentgemma-9b"])
def test_lora_applies_across_families(arch):
    cfg = smoke_variant(get_arch(arch)).replace(lora=LoRAConfig(rank=2, alpha=4.0))
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(0))
    lora, _ = lora_lib.init_lora(params, axes, cfg, key=jax.random.PRNGKey(1))
    assert len(lora) > 0
    merged = lora_lib.merge(params, lora, cfg)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
        assert a.shape == b.shape
