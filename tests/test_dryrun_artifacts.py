"""Regression gate over the dry-run artifacts (skips if not generated)."""

import glob
import json
import os

import pytest

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

files = sorted(glob.glob(os.path.join(DRYRUN, "*.json")))


@pytest.mark.skipif(not files, reason="dry-run artifacts not generated")
def test_all_cells_ok_or_documented_skip():
    bad = []
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            assert rec["shape"] == "long_500k", path
            continue
        if not rec.get("ok"):
            bad.append((os.path.basename(path), rec.get("error")))
    assert not bad, bad


@pytest.mark.skipif(not files, reason="dry-run artifacts not generated")
def test_cell_coverage_complete():
    """10 archs × 4 shapes × 2 meshes accounted for (compiled or skip)."""
    names = {os.path.basename(p) for p in files}
    from repro.config import SHAPES, list_archs

    missing = []
    for arch in list_archs():
        if arch == "fedsllm-100m":
            continue
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                if f"{arch}__{shape}__{mesh}.json" not in names:
                    missing.append((arch, shape, mesh))
    assert not missing, missing


@pytest.mark.skipif(not files, reason="dry-run artifacts not generated")
def test_decode_cells_fit_v5e_hbm():
    """Post-§Perf decode/prefill cells must fit the 16 GB v5e budget
    (train cells for >30B-class models are documented exceptions)."""
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped") or not rec.get("ok") or rec["mesh"] != "single":
            continue
        if rec["kind"] == "decode":
            gb = rec["full"]["memory"]["total_hbm_bytes"] / 1e9
            assert gb < 24.0, (path, gb)  # 16 GB + cost-model DUS overcount


@pytest.mark.skipif(not files, reason="dry-run artifacts not generated")
def test_multi_pod_cells_shard_the_pod_axis():
    """512-device cells must report num_devices=512 and compile green."""
    n = 0
    for path in files:
        if "__multi.json" not in path:
            continue
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        assert rec["num_devices"] == 512, path
        assert rec["ok"], path
        n += 1
    assert n >= 30
