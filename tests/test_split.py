"""Split-learning engine: the Algorithm-2 message flow must equal
end-to-end autodiff exactly, for every model family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LoRAConfig, get_arch, smoke_variant
from repro.core import lora as lora_lib
from repro.core import split
from repro.models import transformer as T

FAMILIES = ["fedsllm-100m", "olmoe-1b-7b", "mamba2-130m", "recurrentgemma-9b",
            "whisper-base"]


def setup(arch, cut=1):
    cfg = smoke_variant(get_arch(arch)).replace(lora=LoRAConfig(rank=4, alpha=8.0))
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(0))
    lora_full, _ = lora_lib.init_lora(params, axes, cfg, key=jax.random.PRNGKey(1))
    # make B nonzero so gradients flow through both factors
    lora_full = jax.tree.map(
        lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(2), x.shape, x.dtype),
        lora_full)
    lc, ls = lora_lib.split_client_server(lora_full, cut)
    B, S = 2, 16
    kt, kl = jax.random.split(jax.random.PRNGKey(3))
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(kt, (B, cfg.encoder_seq, cfg.d_model),
                                                  jnp.float32)
    if cfg.family == "vlm":
        Tv = cfg.vision_tokens
        batch["vision_embeds"] = jax.random.normal(kt, (B, Tv, 1024), jnp.float32)
        batch["tokens"] = batch["tokens"][:, : S - Tv]
    return cfg, params, lc, ls, batch


@pytest.mark.parametrize("arch", FAMILIES)
def test_split_equals_monolithic(arch):
    cfg, params, lc, ls, batch = setup(arch)
    loss_s, dc_s, ds_s, info = split.split_value_and_grad(params, lc, ls, batch, cfg, 1)
    loss_m, dc_m, ds_m = split.monolithic_value_and_grad(params, lc, ls, batch, cfg, 1)
    np.testing.assert_allclose(float(loss_s), float(loss_m), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(dc_s), jax.tree.leaves(dc_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(ds_s), jax.tree.leaves(ds_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)
    assert info["smashed_bytes"] > 0


def test_split_join_roundtrip():
    cfg, params, lc, ls, batch = setup("fedsllm-100m", cut=1)
    joined = lora_lib.join_client_server(lc, ls)
    lc2, ls2 = lora_lib.split_client_server(joined, 1)
    for a, b in zip(jax.tree.leaves(lc), jax.tree.leaves(lc2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ls), jax.tree.leaves(ls2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_smashed_bytes_scale_with_cut_position():
    """Smashed activation volume is (B, S, D) regardless of cut — the
    paper's constant s; gradient volume matches it."""
    cfg, params, lc, ls, batch = setup("fedsllm-100m", cut=1)
    _, _, _, info1 = split.split_value_and_grad(params, lc, ls, batch, cfg, 1)
    assert info1["smashed_bytes"] == info1["grad_bytes"]
