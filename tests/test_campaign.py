"""Multi-round campaign engine: determinism, single-trace compilation,
single-round equivalence, deadline stragglers, elastic cohorts, Lemma-1
stopping, checkpoint/resume, per-round DP keys."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import CampaignResult, Experiment, RoundRecord
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import federated, fedsllm
from repro.data.tokens import TokenStream, client_batches
from repro.sim import events

K = 6        # simulated radio population
COHORT = 4   # clients trained per round (elastic)
ROUNDS = 3


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=K))


@pytest.fixture(scope="module")
def stream(run_cfg):
    return TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)


def _fresh(run_cfg, **kw):
    kw.setdefault("allocator", "EB")
    kw.setdefault("eta", 0.5)
    return Experiment.from_config(run_cfg, **kw)


def _campaign(run_cfg, stream, **kw):
    exp = _fresh(run_cfg)
    deadline = float(np.quantile(exp.timing.total, 0.7))
    kw.setdefault("deadline", deadline)
    kw.setdefault("cohort", COHORT)
    kw.setdefault("resample_channel", True)
    res = exp.run(num_rounds=ROUNDS, stream=stream, **kw)
    return exp, res, kw["deadline"]


@pytest.fixture(scope="module")
def campaign_pair(run_cfg, stream):
    """The same campaign run twice from identical configs."""
    return _campaign(run_cfg, stream), _campaign(run_cfg, stream)


# ---------------------------------------------------------------------------
# Shape of a campaign + the no-recompile guarantee
# ---------------------------------------------------------------------------


def test_campaign_result_structure(campaign_pair):
    exp, res, _ = campaign_pair[0]
    assert isinstance(res, CampaignResult) and res.num_rounds == ROUNDS
    for r, rec in enumerate(res.records):
        assert isinstance(rec, RoundRecord) and rec.round == r
        assert rec.cohort_size == COHORT
        assert np.isfinite(rec.metrics["loss_round_start"])
        assert rec.timing.total.shape == (K,)
        assert rec.round_time > 0
    cum = res.history("loss_round_start")
    assert cum.shape == (ROUNDS,)
    # cumulative simulated wall-clock is strictly increasing
    times = np.asarray([rec.cumulative_time for rec in res.records])
    assert np.all(np.diff(times) > 0) and res.total_time == times[-1]


def test_single_jit_trace_across_rounds(campaign_pair):
    """The acceptance bar: masks/weights/batches vary per round in value
    only — the round function must compile exactly once."""
    for exp, _, _ in campaign_pair:
        assert exp.trace_count == 1


def test_channel_actually_varies_across_rounds(campaign_pair):
    _, res, _ = campaign_pair[0]
    t0, t1 = res.records[0].timing.total, res.records[1].timing.total
    assert not np.allclose(t0, t1)
    a0, a1 = res.records[0].alloc, res.records[1].alloc
    assert not np.allclose(a0.t_c, a1.t_c)


# ---------------------------------------------------------------------------
# Determinism + single-round equivalence
# ---------------------------------------------------------------------------


def test_campaign_determinism_bit_identical(campaign_pair):
    """Same RunConfig + seed ⇒ bit-identical CampaignResult histories."""
    (_, res_a, _), (_, res_b, _) = campaign_pair
    assert res_a.total_time == res_b.total_time
    for ra, rb in zip(res_a.records, res_b.records):
        assert ra.metrics == rb.metrics  # exact float equality
        np.testing.assert_array_equal(ra.client_ids, rb.client_ids)
        np.testing.assert_array_equal(ra.mask, rb.mask)
        assert ra.round_time == rb.round_time
    for a, b in zip(jax.tree.leaves((res_a.state.lora_c, res_a.state.lora_s)),
                    jax.tree.leaves((res_b.state.lora_c, res_b.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_single_round_matches_run_round(run_cfg, stream):
    """run(num_rounds=1, resample_channel=False) ≡ run_round, bit-exact."""
    batches = client_batches(stream, 0, K)
    exp_single = _fresh(run_cfg)
    ref = exp_single.run_round(batches)

    exp_campaign = _fresh(run_cfg)
    res = exp_campaign.run(num_rounds=1, resample_channel=False,
                           batches=batches)
    assert res.num_rounds == 1 and res.records[0].mask is None
    for a, b in zip(jax.tree.leaves((ref.state.lora_c, ref.state.lora_s)),
                    jax.tree.leaves((res.state.lora_c, res.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k, v in ref.metrics.items():
        assert float(v) == res.records[0].metrics[k]
    # and the frozen-channel path keeps the constructor's timing/allocation
    np.testing.assert_array_equal(res.records[0].timing.total,
                                  exp_campaign.timing.total)


# ---------------------------------------------------------------------------
# Deadline stragglers + elastic cohorts
# ---------------------------------------------------------------------------


def test_deadline_mask_wired_from_round_timing(campaign_pair):
    """Straggler masks must come from deadline_mask over THAT round's
    simulated timing — dropping exactly the over-deadline clients."""
    _, res, deadline = campaign_pair[0]
    for rec in res.records:
        assert rec.mask is not None and rec.mask.shape == (COHORT,)
        expect = federated.deadline_mask(rec.timing.total[rec.client_ids],
                                         deadline)
        np.testing.assert_array_equal(rec.mask, expect)
        assert rec.survivors + rec.stragglers == COHORT
    # the chosen 0.7-quantile deadline must actually produce stragglers
    assert res.straggler_rate > 0


def test_elastic_cohort_membership_varies(campaign_pair):
    _, res, _ = campaign_pair[0]
    for rec in res.records:
        ids = rec.client_ids
        assert len(np.unique(ids)) == COHORT and ids.min() >= 0 and ids.max() < K
    assert any(not np.array_equal(res.records[0].client_ids, r.client_ids)
               for r in res.records[1:])


def test_no_deadline_means_no_mask_and_slowest_paces(run_cfg, stream):
    exp = _fresh(run_cfg)
    res = exp.run(num_rounds=1, stream=stream, cohort=COHORT, deadline=None,
                  resample_channel=True)
    rec = res.records[0]
    assert rec.mask is None and rec.survivors == COHORT
    assert rec.round_time == pytest.approx(
        float(np.max(rec.timing.total[rec.client_ids])))


def test_deadline_caps_round_wall_clock():
    total = np.array([1.0, 7.0, 3.0, 9.0])
    ids = np.arange(4)
    assert events.round_wall_clock(total, ids, None) == 9.0
    assert events.round_wall_clock(total, ids, 5.0) == 5.0  # cut at deadline
    assert events.round_wall_clock(total, ids, 50.0) == 9.0  # all made it
    np.testing.assert_array_equal(events.straggler_mask(total, ids, 5.0),
                                  [1.0, 0.0, 1.0, 0.0])
    assert events.straggler_mask(total, ids, None) is None


# ---------------------------------------------------------------------------
# Scenario events
# ---------------------------------------------------------------------------


def test_round_network_keyed_by_round():
    fcfg = FedsLLMConfig(num_clients=5)
    a = events.round_network(fcfg, campaign_seed=0, round_idx=3)
    b = events.round_network(fcfg, campaign_seed=0, round_idx=3)
    c = events.round_network(fcfg, campaign_seed=0, round_idx=4)
    np.testing.assert_array_equal(a.g_c, b.g_c)
    assert not np.array_equal(a.g_c, c.g_c)


def test_retime_allocation_prices_new_gains(run_cfg):
    exp = _fresh(run_cfg)
    fcfg = exp.fcfg
    net2 = events.round_network(fcfg, campaign_seed=1, round_idx=0)
    re = events.retime_allocation(fcfg, net2, exp.alloc)
    # bandwidths/split untouched; uplink times re-priced
    np.testing.assert_array_equal(re.b_c, exp.alloc.b_c)
    assert re.A == exp.alloc.A
    assert not np.allclose(re.t_c, exp.alloc.t_c)
    # an outage (zero rate) becomes +inf — a guaranteed straggler, not a NaN
    dead = events._transmit_time(1e3, np.array([0.0, 1e3]))
    assert np.isinf(dead[0]) and dead[1] == 1.0


def test_reallocate_resolves_every_round(run_cfg, stream):
    exp = _fresh(run_cfg)
    res = exp.run(num_rounds=2, stream=stream, cohort=COHORT,
                  resample_channel=True, reallocate=True)
    a0, a1 = res.records[0].alloc, res.records[1].alloc
    assert a0.strategy == a1.strategy == "EB"
    assert a0.T != a1.T  # each round solved on its own channel draw
    # joint η: every round trains at its own (quantized) solved η, and the
    # per-η round-fn cache keeps compiles ≤ the number of η buckets
    assert all(r.eta in exp.eta_buckets for r in res.records)
    assert exp.trace_count <= len(exp.eta_buckets)


# ---------------------------------------------------------------------------
# Stopping + checkpointing
# ---------------------------------------------------------------------------


def test_lemma1_stopping(run_cfg, stream):
    """Lemma 1 budget ⌈a/(1−η)⌉ caps the campaign."""
    # epsilon0 close to 1 ⇒ tiny a ⇒ small round budget
    fcfg = FedsLLMConfig(num_clients=K, epsilon0=0.9)
    cfg = RunConfig(model=run_cfg.model, shape=run_cfg.shape, fedsllm=fcfg)
    exp = _fresh(cfg)
    budget = fedsllm.global_round_count(exp.fcfg, exp.eta)
    assert budget <= 10  # else this test would be slow
    res = exp.run(num_rounds=50, stream=stream, cohort=COHORT,
                  stop_at_lemma1=True)
    assert res.num_rounds == budget == res.rounds_lemma1
    assert res.stopped_by == "lemma1"


def test_checkpoint_resume_is_bit_identical(run_cfg, stream, tmp_path):
    """Interrupt after 2 of 4 rounds, resume in a NEW process-equivalent
    Experiment: the final state matches the uninterrupted campaign exactly."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    exp_full = _fresh(run_cfg)
    full = exp_full.run(num_rounds=4, **kw)

    ckpt_dir = str(tmp_path / "camp")
    exp_a = _fresh(run_cfg)
    part = exp_a.run(num_rounds=2, checkpoint_dir=ckpt_dir,
                     checkpoint_every=2, **kw)
    assert part.num_rounds == 2

    exp_b = _fresh(run_cfg)  # fresh state — must be overwritten by restore
    rest = exp_b.run(num_rounds=4, checkpoint_dir=ckpt_dir, resume=True, **kw)
    assert [r.round for r in rest.records] == [2, 3]
    assert rest.total_time == pytest.approx(full.total_time)
    for a, b in zip(jax.tree.leaves((full.state.lora_c, full.state.lora_s)),
                    jax.tree.leaves((rest.state.lora_c, rest.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ra, rb in zip(full.records[2:], rest.records):
        assert ra.metrics == rb.metrics

    # a checkpoint that already covers the ask runs nothing, and says so
    noop = _fresh(run_cfg).run(num_rounds=2, checkpoint_dir=ckpt_dir,
                               resume=True, **kw)
    assert noop.num_rounds == 0 and noop.stopped_by == "checkpoint"

    # resuming under a different campaign must refuse, not splice runs
    other = _fresh(run_cfg)
    with pytest.raises(ValueError, match="different campaign"):
        other.run(num_rounds=6, checkpoint_dir=ckpt_dir, resume=True,
                  campaign_seed=123, **kw)
    with pytest.raises(ValueError, match="different campaign"):
        _fresh(run_cfg, eta=0.4).run(num_rounds=6, checkpoint_dir=ckpt_dir,
                                     resume=True, **kw)


def test_resume_refuses_non_campaign_checkpoint(run_cfg, stream, tmp_path):
    """A standard-training checkpoint (no 'round' metadata) must be refused,
    not restored into the campaign state."""
    from repro.checkpoint import Checkpointer

    ck_dir = str(tmp_path / "std")
    Checkpointer(ck_dir).save(5, {"params": jnp.ones(3)})  # no campaign meta
    exp = _fresh(run_cfg)
    with pytest.raises(ValueError, match="not a campaign checkpoint"):
        exp.run(num_rounds=2, stream=stream, checkpoint_dir=ck_dir,
                resume=True)


def test_in_session_continuation_matches_single_run(run_cfg, stream):
    """Rounds are absolute: run(2) then run(4) continues the scenario at
    round 2 (no replay of round 0's draws) and lands bit-identical to one
    uninterrupted run(4)."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    one = _fresh(run_cfg).run(num_rounds=4, **kw)

    exp = _fresh(run_cfg)
    exp.run(num_rounds=2, **kw)
    second = exp.run(num_rounds=4, **kw)
    assert [r.round for r in second.records] == [2, 3]
    for a, b in zip(jax.tree.leaves((one.state.lora_c, one.state.lora_s)),
                    jax.tree.leaves((second.state.lora_c, second.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # simulated wall-clock carries across the continuation too
    assert second.total_time == pytest.approx(one.total_time)
    # re-requesting an already-covered length is a no-op, not a replay
    assert exp.run(num_rounds=4, **kw).num_rounds == 0


def test_round0_resample_differs_from_constructor_draw(run_cfg):
    """The round-0 block-fading redraw must not be the constructor's own
    network realisation (seed-0 stream collision)."""
    exp = _fresh(run_cfg)
    assert exp.seed == 0
    net0 = events.round_network(exp.fcfg, campaign_seed=0, round_idx=0)
    assert not np.array_equal(net0.g_c, exp.net.g_c)


# ---------------------------------------------------------------------------
# Per-round DP keys (the PRNGKey(0)-reuse fix)
# ---------------------------------------------------------------------------


def test_dp_noise_is_fresh_each_round(run_cfg, stream):
    """With key=None the DP noise must differ between global rounds (it used
    to silently reuse PRNGKey(0) every round)."""
    cfg = run_cfg.model
    fcfg = run_cfg.fedsllm
    batches = client_batches(stream, 0, K)
    state0, _ = fedsllm.init_state(cfg, 1, key=jax.random.PRNGKey(0))
    rf = jax.jit(fedsllm.build_round_fn(cfg, fcfg, 1, 0.5,
                                        dp_clip=1.0, dp_noise=1.0))
    s_r0, _ = rf(state0, batches, None, None, None)
    s_r1, _ = rf(state0._replace(round=jnp.ones((), jnp.int32)),
                 batches, None, None, None)
    # identical inputs, different round counter ⇒ different noise draw
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(s_r0.lora_c), jax.tree.leaves(s_r1.lora_c))]
    assert max(diffs) > 0
    # explicit keys stay reproducible
    k = jax.random.PRNGKey(7)
    s_a, _ = rf(state0, batches, None, k, None)
    s_b, _ = rf(state0, batches, None, k, None)
    for a, b in zip(jax.tree.leaves(s_a.lora_c), jax.tree.leaves(s_b.lora_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Argument validation
# ---------------------------------------------------------------------------


def test_campaign_argument_validation(run_cfg, stream):
    exp = _fresh(run_cfg)
    with pytest.raises(ValueError, match="exactly one"):
        exp.run(num_rounds=1)
    with pytest.raises(ValueError, match="exactly one"):
        exp.run(num_rounds=1, stream=stream,
                batches=client_batches(stream, 0, K))
    with pytest.raises(ValueError, match="cohort"):
        exp.run(num_rounds=1, stream=stream, cohort=K + 1)
    with pytest.raises(ValueError, match="num_rounds"):
        exp.run(stream=stream)
    with pytest.raises(ValueError, match="leading axis"):
        exp.run(num_rounds=1, batches=client_batches(stream, 0, K), cohort=2)
    with pytest.raises(ValueError, match="resample_channel"):
        exp.run(num_rounds=1, stream=stream, resample_channel=False,
                reallocate=True)
