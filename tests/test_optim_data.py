"""Optimizers, schedules, data pipeline, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression
from repro.data.blog_feedback import BlogFeedback, ridge_loss_fn
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.tokens import TokenStream
from repro.optim import adamw, adafactor, clip_by_global_norm, cosine_with_warmup, global_norm, sgd

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def quadratic_losses(opt, steps=60):
    """Minimise ||x - t||² — loss must decrease monotonically-ish."""
    t = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    losses = []
    for i in range(steps):
        g = jax.grad(lambda p: jnp.sum((p["x"] - t) ** 2))(params)
        params, state = opt.update(g, state, params, jnp.asarray(i))
        losses.append(float(jnp.sum((params["x"] - t) ** 2)))
    return losses


@pytest.mark.parametrize("make", [
    lambda: sgd(0.1, momentum=0.9),
    lambda: adamw(0.1, weight_decay=0.0),
    lambda: adafactor(0.5),
])
def test_optimizers_converge_on_quadratic(make):
    losses = quadratic_losses(make())
    assert losses[-1] < 0.05 * (losses[0] + 1e-9)


def test_adamw_bf16_params_fp32_moments():
    opt = adamw(0.01)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, s2 = opt.update(g, state, params, jnp.asarray(0))
    assert p2["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    fn = cosine_with_warmup(1.0, warmup_steps=10, total_steps=100)
    vals = [float(fn(jnp.asarray(s))) for s in range(100)]
    assert vals[0] < 0.2                      # warmup starts low
    assert abs(max(vals) - 1.0) < 0.01        # peak at lr
    assert vals[-1] < 0.2                     # decays


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_token_stream_deterministic_and_resumable():
    s = TokenStream(2, 16, 100, seed=3)
    b1 = s.batch_at(7)
    b2 = s.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = s.batch_at(8)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_token_stream_learnable_structure():
    """Labels are next-token shifted; bigram structure present."""
    s = TokenStream(4, 32, 50, seed=0, structure=1.0)
    b = s.batch_at(0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    np.testing.assert_array_equal(labels[:, :-1], toks[:, 1:])


def test_blog_feedback_shapes_and_split():
    ds = BlogFeedback()
    assert ds.X.shape == (60_021, 280)
    X5, y5 = ds.client_shard(5, 50)
    assert X5.shape[0] == 60_021 // 50


def test_blog_feedback_ridge_is_strongly_convex():
    """Assumption (7): γI ≼ ∇²F with γ = λ for the ridge loss."""
    ds = BlogFeedback(num_samples=500)
    loss = ridge_loss_fn(0.1)
    X = jnp.asarray(ds.X[:200])
    y = jnp.asarray(ds.y[:200])
    H = jax.hessian(lambda w: loss(w, X, y))(jnp.zeros(280))
    eig = np.linalg.eigvalsh(np.asarray(H))
    assert eig.min() >= 0.1 - 1e-5


@given(st.integers(2, 10), st.integers(0, 100))
def test_iid_partition_covers_all(K, seed):
    parts = iid_partition(100, K, seed)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(100))


def test_dirichlet_partition_skew():
    labels = np.repeat(np.arange(5), 100)
    parts = dirichlet_partition(labels, 10, alpha=0.1, seed=0)
    assert sum(len(p) for p in parts) == 500
    assert min(len(p) for p in parts) >= 2


def test_dirichlet_partition_impossible_min_size_raises():
    # 4 samples cannot give 8 clients >= 2 each: the failure must be loud
    # (a ValueError naming the achieved sizes), not a silent short return
    labels = np.zeros(4, dtype=np.int64)
    with pytest.raises(ValueError, match="sizes"):
        dirichlet_partition(labels, 8, alpha=0.5, seed=0, min_size=2)


@given(st.integers(2, 8), st.integers(0, 50))
def test_dirichlet_partition_pure_in_seed(K, seed):
    """Same seed ⇒ the identical partition (workloads rebuild batchers from
    (stream.seed, K) and rely on this); different seed ⇒ a different one."""
    labels = np.repeat(np.arange(4), 24)
    a = dirichlet_partition(labels, K, alpha=0.5, seed=seed, min_size=1)
    b = dirichlet_partition(labels, K, alpha=0.5, seed=seed, min_size=1)
    assert len(a) == len(b) == K
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    allidx = np.sort(np.concatenate(a))
    np.testing.assert_array_equal(allidx, np.arange(len(labels)))
    c = dirichlet_partition(labels, K, alpha=0.5, seed=seed + 1, min_size=1)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_topk_compression_and_error_feedback():
    tree = {"g": jnp.asarray(np.random.default_rng(0).normal(size=1000), jnp.float32)}
    sparse, err, bits = compression.compress_tree(tree, 0.1)
    nz = int(jnp.sum(sparse["g"] != 0))
    assert nz <= 110
    # error feedback: sparse + error == original (lossless decomposition)
    np.testing.assert_allclose(np.asarray(sparse["g"] + err["g"]),
                               np.asarray(tree["g"]), rtol=1e-6)
    assert bits < compression.dense_bits(tree)


def test_int8_quantization_bounded_error():
    x = jnp.asarray(np.random.default_rng(1).normal(size=512), jnp.float32)
    q, scale = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6
