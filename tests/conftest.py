import os

# Tests run against the single host CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests prefer the real hypothesis (pip install -e .[test]); in
# offline containers without it, fall back to the seeded sampler so the five
# hypothesis-based modules still collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()

import jax

jax.config.update("jax_enable_x64", False)
