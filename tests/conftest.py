import os

# Tests run against the single host CPU device (the dry-run, and ONLY the
# dry-run, forces 512 placeholder devices).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
