"""Resource allocation (problems (16)/(17)): correctness of both solvers and
the paper's Lemma 3 structural properties at the optimum."""

import numpy as np
import pytest

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import resource_alloc as ra


@pytest.fixture(scope="module")
def setup():
    cfg = FedsLLMConfig(num_clients=10)
    net = dm.sample_network(cfg, seed=0)
    return cfg, net


def test_bandwidth_inversion_exact(setup):
    cfg, net = setup
    r_req = np.linspace(1e3, 2e5, net.K)
    b = dm.bandwidth_for_rate(r_req, net.g_s, net.p_s_max, net.N0)
    ok = np.isfinite(b)
    back = dm.rate(b[ok], net.g_s[ok], net.p_s_max[ok], net.N0)
    np.testing.assert_allclose(back, r_req[ok], rtol=1e-10)


def test_rate_monotone_concave(setup):
    cfg, net = setup
    bs = np.linspace(1e3, 1e6, 200)
    g = np.full_like(bs, net.g_s[0])
    p = np.full_like(bs, net.p_s_max[0])
    r = dm.rate(bs, g, p, net.N0)
    d1 = np.diff(r)
    assert np.all(d1 > 0), "rate must increase with bandwidth"
    assert np.all(np.diff(d1) < 1e-6), "rate must be concave in bandwidth"


def test_solution_satisfies_constraints(setup):
    cfg, net = setup
    a = ra.solve_fixed_eta_exact(cfg, net, 0.1)
    assert a.feasible
    # (17d)/(17e) bandwidth budgets
    assert a.b_c.sum() <= net.B_c * (1 + 1e-6)
    assert a.b_s.sum() <= net.B_s * (1 + 1e-6)
    # (17b)/(17c) rate constraints
    assert np.all(a.t_s * dm.rate(a.b_s, net.g_s, net.p_s_max, net.N0)
                  >= cfg.s_bits * (1 - 1e-6))
    assert np.all(a.t_c * dm.rate(a.b_c, net.g_c, net.p_c_max, net.N0)
                  >= cfg.s_c_bits * (1 - 1e-6))
    # (17a) latency
    T_k = dm.round_latency(cfg, net, a.eta, a.A, a.t_c, a.t_s)
    assert np.max(T_k) <= a.T * (1 + 1e-6)


def test_lemma3_budget_tight_at_optimum(setup):
    """Lemma 3 (eq. 19): t_c + V·t_s exactly exhausts each user's budget."""
    cfg, net = setup
    eta = 0.2
    a = ra.solve_fixed_eta_exact(cfg, net, eta)
    I0 = dm.global_rounds(cfg, eta)
    V = dm.local_iters(cfg, eta)
    R = a.T / I0 - dm.compute_time(cfg, net, eta, a.A)
    np.testing.assert_allclose(a.t_c + V * a.t_s, R, rtol=1e-9)


def test_lemma3_rate_equalities(setup):
    """Lemma 3 (eqs. 20-21): rate constraints hold with equality."""
    cfg, net = setup
    a = ra.solve_fixed_eta_exact(cfg, net, 0.15)
    np.testing.assert_allclose(
        a.b_s * np.log2(1 + net.g_s * net.p_s_max / (net.N0 * a.b_s)),
        cfg.s_bits / a.t_s, rtol=1e-9)
    np.testing.assert_allclose(
        a.b_c * np.log2(1 + net.g_c * net.p_c_max / (net.N0 * a.b_c)),
        cfg.s_c_bits / a.t_c, rtol=1e-9)


def test_exact_beats_or_matches_scipy(setup):
    """The structured solver must find an optimum at least as good as the
    fmincon-equivalent NLP (both solve the same convex problem)."""
    cfg, net = setup
    ex = ra.solve_fixed_eta_exact(cfg, net, 0.1)
    sp = ra.solve_fixed_eta_scipy(cfg, net, 0.1)
    assert ex.T <= sp.T * 1.01


def test_paper_optimality_structure(setup):
    """§III-E: f*=f_max, p*=p_max, A*=A_min are used by construction; check
    latency is monotone in A (so A_min is indeed optimal)."""
    cfg, net = setup
    T = []
    for A in [0.1, 0.3, 0.5]:
        a = ra.solve_fixed_eta_exact(cfg, net, 0.1, A=A)
        T.append(a.T)
    assert T[0] <= T[1] <= T[2]


def test_proposed_beats_baselines(setup):
    cfg, net = setup
    grid = np.arange(0.05, 1.0, 0.05)
    prop = ra.optimize(cfg, net, "proposed", eta_grid=grid)
    eb = ra.optimize(cfg, net, "EB", eta_grid=grid)
    fe = ra.optimize(cfg, net, "FE")
    ba = ra.optimize(cfg, net, "BA")
    assert prop.T <= eb.T * 1.001
    assert prop.T <= fe.T * 1.001
    assert prop.T <= ba.T * 1.001
    assert fe.T <= ba.T * 1.001  # optimising bandwidth can only help
