"""End-to-end behaviour tests for the FedsLLM system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedsLLMConfig, LoRAConfig, get_arch, smoke_variant
from repro.core import delay_model as dm
from repro.core import fedsllm, resource_alloc as ra
from repro.data.tokens import TokenStream, client_batches


@pytest.fixture(scope="module")
def small_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m"))
    return cfg.replace(lora=LoRAConfig(rank=4, alpha=8.0))


def test_fedsllm_round_runs_and_learns(small_cfg):
    """Algorithm 1+2: a few global rounds reduce the mean client loss."""
    fcfg = FedsLLMConfig(num_clients=4)
    cut = 1
    state, _ = fedsllm.init_state(small_cfg, cut)
    round_fn = jax.jit(fedsllm.build_round_fn(small_cfg, fcfg, cut, eta=0.5))
    stream = TokenStream(2, 32, small_cfg.vocab_size, seed=0)
    losses = []
    for r in range(6):
        batches = client_batches(stream, 0, 4)  # fixed data -> must descend
        state, metrics = round_fn(state, batches)
        losses.append(float(metrics["loss_round_start"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


def test_fedsllm_straggler_mask(small_cfg):
    """Dropping one client via mask still yields finite updates."""
    fcfg = FedsLLMConfig(num_clients=4)
    state, _ = fedsllm.init_state(small_cfg, 1)
    round_fn = jax.jit(fedsllm.build_round_fn(small_cfg, fcfg, 1, eta=0.5))
    stream = TokenStream(2, 32, small_cfg.vocab_size, seed=0)
    batches = client_batches(stream, 0, 4)
    mask = jnp.array([1.0, 1.0, 1.0, 0.0])
    state2, metrics = round_fn(state, batches, mask)
    for leaf in jax.tree.leaves(state2.lora_c):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_end_to_end_allocation_pipeline():
    """Network sample -> optimal allocation -> simulated round time."""
    fcfg = FedsLLMConfig(num_clients=8)
    net = dm.sample_network(fcfg, seed=1)
    alloc = ra.optimize(fcfg, net, "proposed",
                        eta_grid=np.arange(0.1, 1.0, 0.1))
    assert alloc.feasible and alloc.T > 0
    timing = fedsllm.simulate_round_time(fcfg, net, alloc, alloc.eta)
    assert np.all(timing.total > 0)
    # total latency over all rounds matches T (up to bisection tolerance)
    I0 = dm.global_rounds(fcfg, alloc.eta)
    assert np.max(timing.total) * I0 <= alloc.T * 1.01
