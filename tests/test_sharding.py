"""Sharding rules: divisibility fallback, axis exclusivity, spec shapes."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import RULESETS, spec_for
from repro.launch.mesh import make_abstract_mesh, make_mesh


@pytest.fixture(scope="module")
def mesh():
    # 1 real device but spec_for math only needs the mesh SHAPE semantics;
    # make_abstract_mesh spans the AbstractMesh API change across jax versions
    return make_abstract_mesh((16, 16), ("data", "model"))


def test_divisible_dims_get_sharded(mesh):
    spec = spec_for((4096, 18432), ("embed", "mlp"), RULESETS["train"], mesh)
    assert spec == P("data", "model")


def test_fused_projection_dim_shards_even_with_awkward_head_count(mesh):
    # starcoder2: 36 heads % 16 != 0, but the fused (D, H·hd) weight dim
    # 4608 % 16 == 0 -> the weight still shards (TP on the flattened dim)
    spec = spec_for((4608, 36 * 128), ("embed", "heads"), RULESETS["train"], mesh)
    assert spec == P("data", "model")


def test_non_divisible_activation_head_axis_dropped(mesh):
    # the unflattened activation (B, S, 36, 128) cannot shard 36 heads 16-way
    spec = spec_for((16, 128, 36, 128), ("batch", "seq", "heads", None),
                    RULESETS["train"], mesh)
    assert spec[0] == "data"
    assert len(spec) <= 2 or spec[2] is None


def test_axis_never_reused_across_dims(mesh):
    spec = spec_for((256, 256, 256), ("embed", "embed", "embed"),
                    RULESETS["train"], mesh)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used)) == 1  # data used once


def test_pod_axis_dropped_on_single_pod(mesh):
    spec = spec_for((256, 4096), ("batch", "seq"), RULESETS["train"], mesh)
    assert spec[0] == "data"  # ("pod","data") -> data only


def test_multi_pod_batch_uses_both():
    mesh3 = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = spec_for((256, 4096), ("batch", "seq"), RULESETS["train"], mesh3)
    assert spec[0] == ("pod", "data")


def test_decode_rules_shard_kv_seq():
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    spec = spec_for((128, 32768, 8, 128), ("batch", "kv_seq", "kv_heads", None),
                    RULESETS["decode"], mesh)
    assert spec[0] == "data"
    assert spec[1] == "model"  # cache length sharded for flash-decode


def test_spec_never_exceeds_rank(mesh):
    spec = spec_for((8,), ("embed",), RULESETS["train"], mesh)
    assert len(spec) <= 1
