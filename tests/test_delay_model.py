"""Delay model (eqs. 8-15): lemma constants, monotonicity, units."""

import numpy as np
import pytest

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm


def test_lemma_constants_match_paper_settings():
    """Paper §IV: ξ=0.1, δ=0.1, ε0=1e-3 (with L=γ=1 normalisation)."""
    cfg = FedsLLMConfig()
    a = dm.lemma_a(cfg)
    v = dm.lemma_v(cfg)
    np.testing.assert_allclose(a, 2.0 / 0.1 * np.log(1e3), rtol=1e-12)
    np.testing.assert_allclose(v, 2.0 / ((2 - 0.1) * 0.1), rtol=1e-12)


def test_rounds_decrease_with_eta_to_zero():
    """Lemma 1: I0 = a/(1-η) increases with η; local iterations v·log2(1/η)
    decrease with η — the tradeoff the optimiser exploits."""
    cfg = FedsLLMConfig()
    etas = np.array([0.1, 0.3, 0.5, 0.7, 0.9])
    I0 = np.array([dm.global_rounds(cfg, e) for e in etas])
    V = np.array([dm.local_iters(cfg, e) for e in etas])
    assert np.all(np.diff(I0) > 0)
    assert np.all(np.diff(V) < 0)


def test_compute_time_monotonicity():
    cfg = FedsLLMConfig(num_clients=5)
    net = dm.sample_network(cfg, seed=0)
    t1 = dm.compute_time(cfg, net, 0.1, A=0.1)
    t2 = dm.compute_time(cfg, net, 0.1, A=0.5)
    assert np.all(t2 > t1), "more client-side layers -> slower (f_k << f_s)"
    t3 = dm.compute_time(cfg, net, 0.5, A=0.1)
    assert np.all(t3 < t1), "looser local accuracy -> fewer local iterations"


def test_channel_units():
    """10 dBm = 10 mW; N0 = -174 dBm/Hz ≈ 4e-21 W/Hz."""
    assert abs(dm.dbm_to_watt(10.0) - 0.01) < 1e-12
    assert abs(dm.dbm_to_watt(-174.0) - 10 ** (-17.4) / 1e3) < 1e-30


def test_network_realisation_shapes():
    cfg = FedsLLMConfig(num_clients=50)
    net = dm.sample_network(cfg, seed=0)
    assert net.K == 50
    assert np.all(net.g_c > 0) and np.all(net.g_c < 1)
    assert np.all((net.C_k >= cfg.cycles_per_param_low)
                  & (net.C_k <= cfg.cycles_per_param_high))
    np.testing.assert_allclose(net.D_k, cfg.num_samples // 50)


def test_latency_formula_eq15():
    """T_k = I0·(τ + t_c + V·t_s) assembled exactly."""
    cfg = FedsLLMConfig(num_clients=3)
    net = dm.sample_network(cfg, seed=2)
    eta, A = 0.2, 0.1
    t_c = np.array([1.0, 2.0, 3.0])
    t_s = np.array([0.1, 0.2, 0.3])
    T = dm.round_latency(cfg, net, eta, A, t_c, t_s)
    I0 = dm.global_rounds(cfg, eta)
    V = dm.local_iters(cfg, eta)
    tau = dm.compute_time(cfg, net, eta, A)
    np.testing.assert_allclose(T, I0 * (tau + t_c + V * t_s), rtol=1e-12)
