"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + no NaNs. (Full configs are exercised only via the
dry-run with ShapeDtypeStructs.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, list_archs, smoke_variant
from repro.models import transformer as T
from repro.models.registry import build_model

ARCHS = [a for a in list_archs() if a != "fedsllm-100m"]


def make_batch(cfg, B=2, S=32, seed=0):
    kt, kl = jax.random.split(jax.random.PRNGKey(seed))
    b = {}
    Tv = 0
    if cfg.family == "vlm":
        Tv = cfg.vision_tokens
        b["vision_embeds"] = jax.random.normal(kt, (B, Tv, 1024), jnp.float32)
    if cfg.family == "encdec":
        b["frame_embeds"] = jax.random.normal(kt, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    b["tokens"] = jax.random.randint(kt, (B, S - Tv), 0, cfg.vocab_size)
    b["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    mask = np.ones((B, S), np.float32)
    mask[:, :Tv] = 0.0
    b["mask"] = jnp.asarray(mask)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_variant(get_arch(arch))
    m = build_model(cfg)
    params, axes = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = smoke_variant(get_arch(arch))
    m = build_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: m.loss(p, batch)[0]))(params)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill+decode logits must match full forward at each position."""
    cfg = smoke_variant(get_arch(arch))
    if cfg.family == "vlm":
        cfg = cfg.replace(vision_tokens=0)  # compare pure-text path
    m = build_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "encdec":
        batch["frame_embeds"] = jax.random.normal(jax.random.PRNGKey(2),
                                                  (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    full_logits, _ = m.forward(params, batch)

    # prefill first half, decode the rest one token at a time
    half = S // 2
    cache = T.init_cache(cfg, B, S)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :half]
    pre_batch["labels"] = toks[:, :half]
    enc_out = T._run_encoder(params, batch, cfg) if cfg.family == "encdec" else None
    logits_p, cache = T.prefill(params, pre_batch, cfg, cache)
    np.testing.assert_allclose(np.asarray(logits_p[:, :half]),
                               np.asarray(full_logits[:, :half]),
                               rtol=2e-2, atol=2e-2)
    for i in range(half, S):
        logits_i, cache = T.decode_step(params, toks[:, i:i + 1], cache,
                                        jnp.asarray(i, jnp.int32), cfg, enc_out=enc_out)
        np.testing.assert_allclose(np.asarray(logits_i[:, 0]),
                                   np.asarray(full_logits[:, i]),
                                   rtol=3e-2, atol=3e-2)
