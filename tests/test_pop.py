"""Population axis (repro.pop): exact/compact/meanfield — compaction
equivalence, O(cohort) sampling, mean-field queue validation against the
exact DES and the analytic M/D/1 / PS references, checkpoint identity."""

import jax
import numpy as np
import pytest

from repro.api import Experiment, populations
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import federated
from repro.data.tokens import TokenStream
from repro.des import queueing
from repro.des.schedules import RoundPlan
from repro.net.topology import EdgeCloudTopology
from repro.pop import (CompactPopulation, ExactPopulation,
                       MeanFieldPopulation, get_population,
                       meanfield_backhaul_hop)

K = 12       # simulated population (bigger than the cohort — compaction real)
COHORT = 4
ROUNDS = 3


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=K))


@pytest.fixture(scope="module")
def stream(run_cfg):
    return TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)


def _fresh(run_cfg, **kw):
    kw.setdefault("allocator", "EB")
    kw.setdefault("topology", "edge-cloud")
    kw.setdefault("scenario", "geo-blockfade")
    kw.setdefault("schedule", "async")
    return Experiment.from_config(run_cfg, **kw)


def _state_leaves(state):
    return [np.asarray(x) for x in jax.tree.leaves((state.lora_c,
                                                    state.lora_s))]


# ---------------------------------------------------------------------------
# Registry + resolution
# ---------------------------------------------------------------------------


def test_registry_names_and_resolution():
    assert set(populations.names()) >= {"exact", "compact", "meanfield"}
    assert isinstance(get_population("exact"), ExactPopulation)
    assert isinstance(get_population("compact"), CompactPopulation)
    assert isinstance(get_population("meanfield"), MeanFieldPopulation)
    inst = CompactPopulation(window=7)
    assert get_population(inst) is inst
    with pytest.raises(KeyError):
        get_population("fluid")


def test_exact_is_the_default_and_every_hook_is_identity(run_cfg):
    exp = _fresh(run_cfg, schedule="sync")
    assert exp.population.name == "exact"
    pop = ExactPopulation()
    pop.begin_campaign(100, 8, 0)
    plan = RoundPlan(round=0, mask=np.ones(5), round_time=1.0)
    out, ids = pop.compact_plan(plan, np.arange(5), 0)
    assert out is plan
    np.testing.assert_array_equal(ids, np.arange(5))
    assert pop.timeline_clients() is None
    assert pop.queued_hop(None, None, None, None, None) is None
    batches = {"x": np.ones(3)}
    assert pop.device_batch(batches) is batches


# ---------------------------------------------------------------------------
# O(cohort) client sampling (satellite: federated.client_sample)
# ---------------------------------------------------------------------------


def test_client_sample_small_k_bit_identical_to_legacy():
    """K ≤ SAMPLE_MIN_CLIENTS keeps the legacy rng.choice draw bit-exactly
    (campaign goldens at the paper's K=8–64 depend on it)."""
    for round_idx, num_clients, cohort, seed in [(0, 8, 4, 0), (3, 50, 10, 7),
                                                 (11, 64, 16, 2)]:
        got = federated.client_sample(round_idx, num_clients, cohort,
                                      seed=seed)
        rng = np.random.default_rng(seed * 1_000_003 + round_idx)
        want = np.sort(rng.choice(num_clients,
                                  size=min(cohort, num_clients),
                                  replace=False))
        np.testing.assert_array_equal(got, want)


def test_client_sample_large_k_properties():
    """Above the legacy threshold the Floyd draw must stay deterministic,
    sorted, unique, in-range and cohort-sized — without materialising a
    length-K permutation."""
    Kbig = 100_000
    s1 = federated.client_sample(5, Kbig, 32, seed=3)
    s2 = federated.client_sample(5, Kbig, 32, seed=3)
    np.testing.assert_array_equal(s1, s2)
    assert len(s1) == 32 and len(np.unique(s1)) == 32
    assert s1.min() >= 0 and s1.max() < Kbig
    assert np.all(np.diff(s1) > 0)
    # different rounds / seeds give different cohorts
    assert not np.array_equal(s1, federated.client_sample(6, Kbig, 32, seed=3))
    assert not np.array_equal(s1, federated.client_sample(5, Kbig, 32, seed=4))


# ---------------------------------------------------------------------------
# Compaction: fixed window, single trace, bit-identical aggregation
# ---------------------------------------------------------------------------


def test_compact_plan_window_semantics():
    pop = CompactPopulation(window=5)
    pop.begin_campaign(20, 4, 0)
    mask = np.zeros(20)
    mask[[3, 17]] = 1.0
    plan = RoundPlan(round=0, mask=mask, round_time=1.0,
                     client_ids=np.arange(20),
                     weight_scale=np.linspace(0.1, 2.0, 20),
                     staleness=np.arange(20, dtype=float))
    out, ids = pop.compact_plan(plan, np.arange(20), round_idx=2)
    assert len(ids) == 5 and np.all(np.diff(ids) > 0)
    assert {3, 17} <= set(ids.tolist())  # arrivals always ride the window
    np.testing.assert_array_equal(out.client_ids, ids)
    np.testing.assert_array_equal(out.mask, mask[ids])
    np.testing.assert_array_equal(out.weight_scale, plan.weight_scale[ids])
    # pure in round_idx: the identical call compacts identically (resume)
    out2, ids2 = pop.compact_plan(plan, np.arange(20), round_idx=2)
    np.testing.assert_array_equal(ids, ids2)
    # a different round rotates the fill through the pool
    _, ids3 = pop.compact_plan(plan, np.arange(20), round_idx=3)
    assert not np.array_equal(ids, ids3)


def test_compact_plan_refuses_overfull_window():
    pop = CompactPopulation(window=2)
    pop.begin_campaign(10, 2, 0)
    plan = RoundPlan(round=0, mask=np.ones(10), round_time=1.0,
                     client_ids=np.arange(10))
    with pytest.raises(ValueError, match="window"):
        pop.compact_plan(plan, np.arange(10), 0)


def test_compact_plan_identity_for_sync_plans_and_full_windows():
    pop = CompactPopulation()
    pop.begin_campaign(6, 6, 0)  # window == K: degenerates to exact
    plan = RoundPlan(round=0, mask=np.ones(6), round_time=1.0,
                     client_ids=np.arange(6))
    out, ids = pop.compact_plan(plan, np.arange(6), 0)
    assert out is plan
    sync_plan = RoundPlan(round=0, mask=None, round_time=1.0)
    pop2 = CompactPopulation(window=2)
    pop2.begin_campaign(6, 2, 0)
    out2, _ = pop2.compact_plan(sync_plan, np.arange(2), 0)
    assert out2 is sync_plan


def test_compact_campaign_matches_exact_bit_identical(run_cfg, stream):
    """The tentpole equivalence: a compacted async campaign reproduces the
    exact K-sized rounds' final model state bit-for-bit (masked window
    members contribute exactly +0.0 to the mean-family sums), with the
    round function still traced exactly once — at window shape."""
    kw = dict(num_rounds=ROUNDS, stream=stream, cohort=COHORT)
    exp_exact = _fresh(run_cfg, population="exact")
    res_exact = exp_exact.run(**kw)
    exp_comp = _fresh(run_cfg, population="compact")
    res_comp = exp_comp.run(**kw)
    assert exp_comp.trace_count == 1
    for a, b in zip(_state_leaves(res_exact.state),
                    _state_leaves(res_comp.state)):
        np.testing.assert_array_equal(a, b)
    # the compacted rounds really were window-sized, not K-sized
    assert all(len(r.client_ids) < K for r in res_comp.records)
    assert all(len(r.client_ids) == K for r in res_exact.records)
    assert res_comp.population == "compact"
    # simulated timing is untouched by device compaction (timeline is exact)
    assert res_comp.total_time == pytest.approx(res_exact.total_time)


def test_meanfield_campaign_runs_with_restricted_timeline(run_cfg, stream):
    exp = _fresh(run_cfg, population=MeanFieldPopulation(reps=6))
    res = exp.run(num_rounds=ROUNDS, stream=stream, cohort=COHORT)
    assert exp.trace_count == 1
    pop = exp.population
    assert pop.rep_ids is not None and len(pop.rep_ids) == 6
    assert np.all(np.diff(pop.rep_ids) > 0) and pop.rep_ids.max() < K
    # every trained client is a representative (timeline only launches reps)
    for r in res.records:
        assert set(r.client_ids.tolist()) <= set(pop.rep_ids.tolist())
        assert np.isfinite(r.round_time) and r.round_time > 0
    assert res.population == "meanfield"


def test_meanfield_reallocate_solves_on_representatives(run_cfg, stream):
    """Under reallocate=True the per-cell solves run on the representative
    members with the pool scaled by multiplicity, and every client still
    gets a finite priced allocation (broadcast from its nearest rep)."""
    exp = _fresh(run_cfg, population=MeanFieldPopulation(reps=6))
    res = exp.run(num_rounds=2, stream=stream, cohort=COHORT,
                  reallocate=True)
    for rec in res.records:
        assert rec.alloc.feasible
        assert np.isfinite(rec.alloc.T)
        assert np.all(np.isfinite(np.asarray(rec.alloc.t_c)))
        assert len(np.asarray(rec.alloc.t_c)) == K  # full-K broadcast


# ---------------------------------------------------------------------------
# Mean-field queue validation (the docstring-named tests)
# ---------------------------------------------------------------------------


def _poisson_cells(seed, K_jobs=600, M=2, rate=45.0):
    rng = np.random.default_rng(seed)
    assign = np.repeat(np.arange(M), K_jobs // M)
    totals = np.empty(K_jobs)
    for m in range(M):
        totals[assign == m] = np.cumsum(
            rng.exponential(1.0 / rate, K_jobs // M))
    return assign, totals


@pytest.mark.parametrize("model", ["fifo", "ps"])
@pytest.mark.parametrize("seed", [1, 3])
def test_meanfield_waits_match_exact_des_within_10pct(model, seed):
    """The acceptance bar: at a K where both run, the mean-field per-cell
    arrival-rate model prices the shared backhaul within 10% of the exact
    per-job queue replay (Poisson arrivals, ρ ≈ 0.45 over the span)."""
    K_jobs = 600
    fcfg = FedsLLMConfig(num_clients=K_jobs)
    s = 0.005  # deterministic service per delta
    topo = EdgeCloudTopology(num_edges=2, backhaul_bps=fcfg.s_c_bits / s,
                             backhaul_model=model)
    assign, totals = _poisson_cells(seed, K_jobs=K_jobs)
    exact = topo._queued_backhaul(fcfg, assign, 0.3, totals)
    mf = meanfield_backhaul_hop(topo, fcfg, assign, 0.3, totals)
    assert mf.shape == exact.shape
    rel = abs(float(np.mean(mf)) - float(np.mean(exact))) \
        / float(np.mean(exact))
    assert rel < 0.10, f"{model} mean hop off by {rel:.1%}"


@pytest.mark.parametrize("model,ref", [
    ("fifo", queueing.md1_mean_wait), ("ps", queueing.ps_mean_wait)])
def test_meanfield_matches_md1_poisson(model, ref):
    """The analytic leg: on a single Poisson-fed cell the summed arrival
    rate recovers the M/D/1 (FIFO) / PS reference mean wait."""
    K_jobs, rate, s = 800, 90.0, 0.005  # rho = 0.45
    fcfg = FedsLLMConfig(num_clients=K_jobs)
    topo = EdgeCloudTopology(num_edges=1, backhaul_bps=fcfg.s_c_bits / s,
                             backhaul_model=model)
    assign, totals = _poisson_cells(7, K_jobs=K_jobs, M=1, rate=rate)
    mf = meanfield_backhaul_hop(topo, fcfg, assign, 0.3, totals)
    service = queueing.service_seconds(
        np.full(K_jobs, fcfg.s_c_bits), topo.backhaul_bps)
    mean_wait = float(np.mean(mf - service))
    assert mean_wait == pytest.approx(ref(rate, s), rel=0.10)


def test_meanfield_hop_zero_for_outage_clients():
    fcfg = FedsLLMConfig(num_clients=6)
    topo = EdgeCloudTopology(num_edges=2, backhaul_bps=1e6,
                             backhaul_model="fifo")
    assign = np.array([0, 0, 0, 1, 1, 1])
    totals = np.array([0.1, 0.2, np.inf, 0.1, 0.3, 0.5])
    hop = meanfield_backhaul_hop(topo, fcfg, assign, 0.3, totals)
    assert hop[2] == 0.0
    assert np.all(hop[np.isfinite(totals)] > 0)


def test_meanfield_queued_hop_wired_into_topology():
    """backhaul_hop dispatches to the population's analytic model, and an
    unbound (or exact) population keeps the exact queue replay."""
    fcfg = FedsLLMConfig(num_clients=8)
    topo = EdgeCloudTopology(num_edges=2, backhaul_bps=1e6,
                             backhaul_model="fifo")
    assign = np.arange(8) % 2
    totals = np.linspace(0.1, 0.8, 8)
    pop = MeanFieldPopulation()
    via_topo = topo.backhaul_hop(fcfg, assign, 0.3, totals, population=pop)
    direct = meanfield_backhaul_hop(topo, fcfg, assign, 0.3, totals)
    np.testing.assert_array_equal(via_topo, direct)
    exact = topo.backhaul_hop(fcfg, assign, 0.3, totals)
    np.testing.assert_array_equal(
        exact, topo._queued_backhaul(fcfg, assign, 0.3, totals))
    np.testing.assert_array_equal(
        exact, topo.backhaul_hop(fcfg, assign, 0.3, totals,
                                 population=ExactPopulation()))


# ---------------------------------------------------------------------------
# Checkpoint/resume identity (satellite: guard family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("population", ["compact", "meanfield"])
def test_population_campaign_resume_bit_identical(run_cfg, stream, tmp_path,
                                                  population):
    kw = dict(stream=stream, cohort=COHORT)
    full = _fresh(run_cfg, population=population).run(num_rounds=4, **kw)

    ckpt = str(tmp_path / population)
    part = _fresh(run_cfg, population=population).run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    assert part.num_rounds == 2
    rest = _fresh(run_cfg, population=population).run(
        num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    assert [r.round for r in rest.records] == [2, 3]
    for a, b in zip(_state_leaves(full.state), _state_leaves(rest.state)):
        np.testing.assert_array_equal(a, b)
    for ra, rb in zip(full.records[2:], rest.records):
        assert ra.metrics == rb.metrics
        np.testing.assert_array_equal(ra.client_ids, rb.client_ids)


def test_resume_refuses_population_mismatch(run_cfg, stream, tmp_path):
    """Same guard family as scenario/topology/schedule digests: resuming
    under a different population name OR window size must refuse."""
    kw = dict(stream=stream, cohort=COHORT)
    ckpt = str(tmp_path / "pop")
    _fresh(run_cfg, population="compact").run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    with pytest.raises(ValueError, match="different campaign"):
        _fresh(run_cfg, population="exact").run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    with pytest.raises(ValueError, match="different campaign"):
        _fresh(run_cfg, population=CompactPopulation(window=3)).run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)


# ---------------------------------------------------------------------------
# Sweep axis
# ---------------------------------------------------------------------------


def test_sweep_population_axis(run_cfg, stream):
    from repro.sim.sweep import run_sweep

    res = run_sweep(run_cfg, 2, scenarios=("geo-blockfade",),
                    allocators=("EB",), topologies=("edge-cloud",),
                    schedules=("async",),
                    populations=("exact", "compact"),
                    stream=stream, cohort=COHORT)
    assert res.populations == ("exact", "compact")
    assert {r["population"] for r in res.records} == {"exact", "compact"}
    rows = res.summary()
    assert {r["population"] for r in rows} == {"exact", "compact"}
    cell = res.cell("geo-blockfade", "EB", population="compact")
    assert len(cell) == 2
    with pytest.raises(ValueError, match="population"):
        res.cell("geo-blockfade", "EB")
