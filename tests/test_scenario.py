"""Scenario API: registry contract, per-family invariants, bit-compat of the
default ``blockfade`` with the pre-scenario engine, joint-η reallocation
trace accounting, checkpoint scenario guard, and the sweep runner."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import Experiment, get_scenario, scenarios
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import delay_model as dm
from repro.core.resource_alloc import quantize_eta
from repro.sim import events
from repro.sim.scenario import (DriftScenario, HeteroScenario, OutageScenario,
                                Scenario, ShadowingScenario,
                                SHADOW_STREAM_TAG)
from repro.sim.sweep import run_sweep

K = 6
COHORT = 4


@pytest.fixture(scope="module")
def fcfg():
    return FedsLLMConfig(num_clients=K)


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=K))


@pytest.fixture(scope="module")
def stream(run_cfg):
    from repro.data.tokens import TokenStream

    return TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)


def _fresh(run_cfg, **kw):
    kw.setdefault("allocator", "EB")
    kw.setdefault("eta", 0.5)
    return Experiment.from_config(run_cfg, **kw)


# ---------------------------------------------------------------------------
# Registry contract (the fourth axis mirrors the other three)
# ---------------------------------------------------------------------------


def test_scenario_registry_contents():
    assert {"frozen", "blockfade", "geo-blockfade", "drift", "hetero",
            "outage", "shadowing"} <= set(scenarios.names())


def test_unknown_scenario_lists_known_names():
    with pytest.raises(KeyError) as exc:
        get_scenario("definitely-not-registered")
    for name in scenarios.names():
        assert name in str(exc.value)


def test_unknown_scenario_in_experiment(run_cfg):
    with pytest.raises(KeyError, match="unknown scenario"):
        Experiment.from_config(run_cfg, scenario="nope")


def test_get_scenario_accepts_instances():
    drift = DriftScenario(step_m=50.0)
    assert get_scenario(drift) is drift
    assert isinstance(get_scenario("drift"), DriftScenario)


# ---------------------------------------------------------------------------
# Determinism: every registered scenario is a pure function of (seed, round)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted({"frozen", "blockfade",
                                         "geo-blockfade", "drift", "hetero",
                                         "outage", "shadowing"}))
def test_scenario_deterministic_in_seed_and_round(name, fcfg):
    sc = get_scenario(name)
    a = sc.round_network(fcfg, campaign_seed=3, round_idx=5)
    b = sc.round_network(fcfg, campaign_seed=3, round_idx=5)
    for f in ("g_c", "g_s", "C_k", "D_k", "f_max"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    # a different campaign seed is a different realisation
    c = sc.round_network(fcfg, campaign_seed=4, round_idx=5)
    assert not np.array_equal(a.g_c, c.g_c)
    # and the constructor draw + digest are reproducible too
    np.testing.assert_array_equal(sc.initial_network(fcfg, 0).g_c,
                                  sc.initial_network(fcfg, 0).g_c)
    assert sc.digest(fcfg, 0) == sc.digest(fcfg, 0)


@pytest.mark.parametrize("name", ["blockfade", "geo-blockfade", "drift",
                                  "hetero", "outage", "shadowing"])
def test_fading_scenarios_vary_across_rounds(name, fcfg):
    sc = get_scenario(name)
    assert not np.array_equal(sc.round_network(fcfg, 0, 1).g_c,
                              sc.round_network(fcfg, 0, 2).g_c)


# ---------------------------------------------------------------------------
# blockfade: bit-identical to the pre-scenario (PR 2) engine
# ---------------------------------------------------------------------------


def test_blockfade_matches_legacy_draws(fcfg):
    """The default scenario IS the legacy semantics: constructor draw ==
    sample_network(seed), round draw == the round-keyed full redraw."""
    sc = get_scenario("blockfade")
    np.testing.assert_array_equal(sc.initial_network(fcfg, 7).g_c,
                                  dm.sample_network(fcfg, seed=7).g_c)
    legacy = dm.sample_network(fcfg, seed=events.round_seed(7, 3))
    drawn = sc.round_network(fcfg, 7, 3)
    np.testing.assert_array_equal(drawn.g_c, legacy.g_c)
    np.testing.assert_array_equal(drawn.g_s, legacy.g_s)
    np.testing.assert_array_equal(drawn.C_k, legacy.C_k)
    # and events.round_network without a scenario is the same draw
    np.testing.assert_array_equal(
        events.round_network(fcfg, 7, 3).g_c, drawn.g_c)


def test_default_scenario_campaign_bit_identical_to_explicit(run_cfg, stream):
    """Experiment() == Experiment(scenario="blockfade"), bit-exact through a
    resampled campaign (the PR 2 golden behaviour is the default)."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    res_default = _fresh(run_cfg).run(num_rounds=2, **kw)
    res_named = _fresh(run_cfg, scenario="blockfade").run(num_rounds=2, **kw)
    assert res_default.total_time == res_named.total_time
    assert res_default.scenario == res_named.scenario == "blockfade"
    for ra, rb in zip(res_default.records, res_named.records):
        assert ra.metrics == rb.metrics
    for a, b in zip(
            jax.tree.leaves((res_default.state.lora_c, res_default.state.lora_s)),
            jax.tree.leaves((res_named.state.lora_c, res_named.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# geo-blockfade: geometry invariance (ROADMAP open item #1)
# ---------------------------------------------------------------------------


def test_geo_blockfade_geometry_invariance(fcfg):
    """Positions and path loss constant across rounds; gains still fade."""
    sc = get_scenario("geo-blockfade")
    nets = [sc.round_network(fcfg, 0, r) for r in range(4)]
    for n in nets[1:]:
        np.testing.assert_array_equal(n.xy, nets[0].xy)
        np.testing.assert_array_equal(n.pl_db, nets[0].pl_db)
        np.testing.assert_array_equal(n.C_k, nets[0].C_k)
        np.testing.assert_array_equal(n.f_max, nets[0].f_max)
        assert not np.array_equal(n.g_c, nets[0].g_c)
    # the campaign-facing invariant: after N resampled rounds the
    # experiment's network still sits on the campaign's large-scale draw
    ls = sc.large_scale(fcfg, 0)
    np.testing.assert_array_equal(nets[-1].xy, ls.xy)


def test_geo_blockfade_campaign_keeps_geometry(run_cfg, stream):
    exp = _fresh(run_cfg, scenario="geo-blockfade")
    exp.run(num_rounds=3, stream=stream, cohort=COHORT,
            resample_channel=True)
    ls = exp.scenario.large_scale(exp.fcfg, exp.seed)
    np.testing.assert_array_equal(exp.net.xy, ls.xy)
    np.testing.assert_array_equal(exp.net.pl_db, ls.pl_db)


# ---------------------------------------------------------------------------
# frozen: resampling degenerates to the frozen-channel run
# ---------------------------------------------------------------------------


def test_frozen_resample_equals_frozen_run(run_cfg, stream):
    """frozen + resample_channel=True == resample_channel=False, bit-exact:
    the per-round "redraw" returns the same realisation, and retiming an
    equal-bandwidth allocation under identical gains re-derives identical
    uplink times."""
    kw = dict(stream=stream, cohort=COHORT)
    res_resample = _fresh(run_cfg, scenario="frozen").run(
        num_rounds=2, resample_channel=True, **kw)
    res_frozen = _fresh(run_cfg, scenario="frozen").run(
        num_rounds=2, resample_channel=False, **kw)
    assert res_resample.total_time == res_frozen.total_time
    for ra, rb in zip(res_resample.records, res_frozen.records):
        assert ra.metrics == rb.metrics
        assert ra.round_time == rb.round_time
        np.testing.assert_array_equal(ra.timing.total, rb.timing.total)
    for a, b in zip(
            jax.tree.leaves((res_resample.state.lora_c, res_resample.state.lora_s)),
            jax.tree.leaves((res_frozen.state.lora_c, res_frozen.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# drift / hetero / outage family invariants
# ---------------------------------------------------------------------------


def test_drift_moves_users_within_the_cell(fcfg):
    sc = get_scenario("drift")
    n0 = sc.round_network(fcfg, 0, 0)
    n9 = sc.round_network(fcfg, 0, 9)
    assert not np.array_equal(n0.xy, n9.xy)  # users actually moved
    assert not np.array_equal(n0.pl_db, n9.pl_db)  # path loss followed
    half = fcfg.area_m / 2.0
    assert np.all(np.abs(n9.xy) <= half)  # bounded by the cell
    # heterogeneity is large-scale: it does NOT drift
    np.testing.assert_array_equal(n0.C_k, n9.C_k)
    # round 0 is the campaign's round-0 geometry (no pre-move)
    np.testing.assert_array_equal(n0.xy, sc.large_scale(fcfg, 0).xy)


def test_hetero_assigns_device_tiers(fcfg):
    sc = get_scenario("hetero")
    net = sc.round_network(fcfg, 0, 0)
    assert set(np.unique(net.f_max)) <= set(sc.f_tiers_hz)
    assert len(np.unique(net.f_max)) > 1  # actual heterogeneity at K=6
    # tiers are part of the campaign identity
    assert sc.digest(fcfg, 0) != get_scenario("geo-blockfade").digest(fcfg, 0)
    # geometry stays fixed like geo-blockfade
    np.testing.assert_array_equal(net.xy, sc.round_network(fcfg, 0, 5).xy)


def test_outage_applies_exact_burst_penalty(fcfg):
    """With prob=1 every user fades by exactly depth_db vs geo-blockfade
    (same large-scale state, same shadowing stream); with prob=0 the two
    scenarios coincide."""
    geo = get_scenario("geo-blockfade")
    sure = OutageScenario(prob=1.0, depth_db=20.0)
    off = OutageScenario(prob=0.0)
    g_geo = geo.round_network(fcfg, 0, 2).g_c
    np.testing.assert_allclose(sure.round_network(fcfg, 0, 2).g_c / g_geo,
                               dm.db_to_lin(-20.0), rtol=1e-12)
    np.testing.assert_array_equal(off.round_network(fcfg, 0, 2).g_c, g_geo)


def test_outage_bursts_span_whole_windows(fcfg):
    sc = OutageScenario(prob=0.5, depth_db=30.0, burst_rounds=3)
    # membership is constant within a window and keyed by the window index
    for r in (0, 1, 2):
        np.testing.assert_array_equal(sc.extra_loss_db(fcfg, 0, r),
                                      sc.extra_loss_db(fcfg, 0, 0))
    windows = {tuple(sc.extra_loss_db(fcfg, 0, w * 3)) for w in range(8)}
    assert len(windows) > 1  # bursts actually switch between windows


def test_scenario_parameter_validation():
    with pytest.raises(ValueError, match="prob"):
        OutageScenario(prob=1.5)
    with pytest.raises(ValueError, match="burst_rounds"):
        OutageScenario(burst_rounds=0)
    with pytest.raises(ValueError, match="align"):
        HeteroScenario(f_tiers_hz=(1e9,), p_tiers_dbm=(10.0, 4.0))
    with pytest.raises(ValueError, match="rho"):
        ShadowingScenario(rho=1.0)
    with pytest.raises(ValueError, match="rho"):
        ShadowingScenario(rho=-0.1)


# ---------------------------------------------------------------------------
# shadowing: Gauss-Markov AR(1) correlated shadowing (ROADMAP open item #1)
# ---------------------------------------------------------------------------


def test_shadowing_pure_in_seed_and_round(fcfg):
    sc = ShadowingScenario(rho=0.7)
    np.testing.assert_array_equal(sc.shadow_db(fcfg, 3, 5),
                                  sc.shadow_db(fcfg, 3, 5))
    assert not np.array_equal(sc.shadow_db(fcfg, 3, 5),
                              sc.shadow_db(fcfg, 4, 5))


def test_shadowing_follows_ar1_recursion_exactly(fcfg):
    """S_r == ρ·S_{r-1} + σ·sqrt(1-ρ²)·ε_r with ε_r from the tagged stream —
    the process is AR(1) by construction, not just approximately."""
    rho = 0.8
    sc = ShadowingScenario(rho=rho)
    seed = 11
    eps = np.random.default_rng([seed, SHADOW_STREAM_TAG]).normal(
        size=(8, 2, fcfg.num_clients))
    for r in range(1, 8):
        expect = (rho * sc.shadow_db(fcfg, seed, r - 1)
                  + fcfg.shadow_std_db * np.sqrt(1 - rho**2) * eps[r])
        np.testing.assert_allclose(sc.shadow_db(fcfg, seed, r), expect,
                                   rtol=1e-10, atol=1e-10)
    # round 0 is the stationary draw σ·ε_0
    np.testing.assert_allclose(sc.shadow_db(fcfg, seed, 0),
                               fcfg.shadow_std_db * eps[0], rtol=1e-12)


def test_shadowing_autocorrelation_and_marginal(fcfg):
    """Lag-1 sample autocorrelation ≈ ρ and the per-round marginal keeps the
    paper's N(0, σ²) (stationary variance independent of the round)."""
    rho = 0.9
    sc = ShadowingScenario(rho=rho)
    fields = np.stack([sc.shadow_db(fcfg, s, r)
                       for s in range(40) for r in range(2)])  # (80, 2, K)
    pairs = fields.reshape(40, 2, -1)
    x, y = pairs[:, 0, :].ravel(), pairs[:, 1, :].ravel()
    corr = np.corrcoef(x, y)[0, 1]
    assert abs(corr - rho) < 0.1
    # stationary marginal: std ≈ shadow_std_db at a late round too
    late = np.stack([ShadowingScenario(rho=rho).shadow_db(fcfg, s, 9)
                     for s in range(60)])
    assert abs(np.std(late) - fcfg.shadow_std_db) < 1.0


def test_shadowing_rho_zero_is_iid_innovations(fcfg):
    """ρ=0 degenerates to i.i.d. per-round draws from the tagged stream."""
    sc = ShadowingScenario(rho=0.0)
    eps = np.random.default_rng([0, SHADOW_STREAM_TAG]).normal(
        size=(3, 2, fcfg.num_clients))
    np.testing.assert_allclose(sc.shadow_db(fcfg, 0, 2),
                               fcfg.shadow_std_db * eps[2], rtol=1e-12)


def test_shadowing_network_keeps_geometry_and_digest_covers_rho(fcfg):
    sc = ShadowingScenario()
    n1, n5 = sc.round_network(fcfg, 0, 1), sc.round_network(fcfg, 0, 5)
    np.testing.assert_array_equal(n1.xy, n5.xy)  # geometry is large-scale
    assert not np.array_equal(n1.g_c, n5.g_c)    # the field still evolves
    assert (ShadowingScenario(rho=0.5).digest(fcfg, 0)
            != ShadowingScenario(rho=0.9).digest(fcfg, 0))


# ---------------------------------------------------------------------------
# warm realloc default: cross-scenario optimality audit (ROADMAP item #3)
# ---------------------------------------------------------------------------


def test_warm_realloc_optimality_audit_across_scenarios(fcfg):
    """The campaign default ``realloc_search="warm"`` (±5-step window around
    the constructor's solved η*) must match the full 0.01-grid sweep to
    <1e-6 relative delay on per-round draws of EVERY registered scenario —
    the audit that justified flipping the default (ROADMAP open item #3)."""
    from repro.core import resource_alloc as ra

    for name in scenarios.names():
        sc = get_scenario(name)
        anchor = ra.optimize(fcfg, sc.initial_network(fcfg, 0), "EB",
                             eta_search="coarse")
        for r in (0, 2):
            net = sc.round_network(fcfg, 0, r)
            full = ra.optimize(fcfg, net, "EB")  # paper-faithful full grid
            warm = ra.optimize(fcfg, net, "EB", eta_search="warm",
                               eta0=anchor.eta)
            assert warm.T <= full.T * (1 + 1e-6), (name, r, warm.T, full.T)


def test_warm_realloc_audit_proposed_solver(fcfg):
    """The warm default also holds for the headline 'proposed' exact solver
    (spot-checked — the EB audit above covers every scenario): warm around
    the constructor's η* matches the coarse+refine sweep, whose optimum
    equals the full grid's on smooth T(η)."""
    from repro.core import resource_alloc as ra

    for name in ("geo-blockfade", "hetero"):
        sc = get_scenario(name)
        anchor = ra.optimize(fcfg, sc.initial_network(fcfg, 0), "proposed",
                             eta_search="coarse")
        net = sc.round_network(fcfg, 0, 1)
        full = ra.optimize(fcfg, net, "proposed", eta_search="coarse")
        warm = ra.optimize(fcfg, net, "proposed", eta_search="warm",
                           eta0=anchor.eta)
        assert warm.T <= full.T * (1 + 1e-6), (name, warm.T, full.T)


def test_campaign_default_realloc_search_is_warm(run_cfg, stream):
    """reallocate=True without realloc_search= uses the warm local window —
    bit-identical to asking for it explicitly."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True,
              reallocate=True)
    res_default = _fresh(run_cfg, scenario="geo-blockfade").run(
        num_rounds=2, **kw)
    res_warm = _fresh(run_cfg, scenario="geo-blockfade").run(
        num_rounds=2, realloc_search="warm", **kw)
    for ra_, rb in zip(res_default.records, res_warm.records):
        assert ra_.eta == rb.eta and ra_.alloc.T == rb.alloc.T
        assert ra_.metrics == rb.metrics


def test_custom_scenario_subclass_pluggable(run_cfg, stream):
    """A user-defined Scenario instance plugs straight into Experiment."""

    class DoubledBandwidth(Scenario):
        name = "custom-2xbw"

        def round_large_scale(self, fcfg, campaign_seed, round_idx):
            ls = self.large_scale(fcfg, campaign_seed)
            return dataclasses.replace(ls, B_c=2 * ls.B_c, B_s=2 * ls.B_s)

    exp = _fresh(run_cfg, scenario=DoubledBandwidth())
    res = exp.run(num_rounds=1, stream=stream, cohort=COHORT,
                  resample_channel=True)
    assert res.scenario == "custom-2xbw"
    assert exp.net.B_c == 2 * run_cfg.fedsllm.bandwidth_total_hz


# ---------------------------------------------------------------------------
# Joint-η reallocation: re-solve per round, bounded compile cache
# ---------------------------------------------------------------------------


def test_quantize_eta_grid():
    assert quantize_eta(0.37, 0.05, 0.5) == pytest.approx(0.35)
    assert quantize_eta(0.99, 0.05, 0.5) == 0.5  # clamped to eta_train_max
    assert quantize_eta(0.01, 0.05, 0.5) == pytest.approx(0.05)  # floor
    with pytest.raises(ValueError):
        quantize_eta(0.3, 0.0)


def test_reallocate_resolves_eta_jointly(run_cfg, stream):
    """reallocate=True adopts each round's solved η* (quantized): the round
    function switches buckets without per-round recompiles — trace_count
    stays ≤ the number of η buckets (the acceptance bar)."""
    # constructor pinned far from EB's optimum (η* ≈ 0.95 → bucket 0.5), so
    # the first re-solve provably switches the training η
    exp = _fresh(run_cfg, eta=0.2, scenario="geo-blockfade")
    assert exp.eta == 0.2
    res = exp.run(num_rounds=3, stream=stream, cohort=COHORT,
                  resample_channel=True, reallocate=True)
    max_buckets = int(round(exp.fcfg.eta_train_max / exp.fcfg.eta_bucket))
    assert exp.trace_count <= len(exp.eta_buckets) <= max_buckets
    for rec in res.records:
        assert rec.eta in exp.eta_buckets  # η the round actually trained at
        assert rec.eta == quantize_eta(rec.alloc.eta, exp.fcfg.eta_bucket,
                                       exp.fcfg.eta_train_max)
    assert res.records[0].eta != 0.2  # the re-solve really moved η
    # timing is priced at the adopted η, not the stale constructor η
    assert res.records[0].alloc.eta != 0.2


def test_set_eta_reuses_cached_round_fn(run_cfg, stream):
    from repro.data.tokens import client_batches

    exp = _fresh(run_cfg, eta=0.2)
    batches = client_batches(stream, 0, K)
    exp.run_round(batches)
    assert exp.trace_count == 1
    exp.set_eta(0.5)
    exp.run_round(batches)
    assert exp.trace_count == 2 and exp.eta_buckets == [0.2, 0.5]
    exp.set_eta(0.2)  # back to the first bucket: cached, no new trace
    exp.set_eta(0.52)  # quantizes onto the existing 0.5 bucket
    assert exp.eta == 0.5
    exp.run_round(batches)
    assert exp.trace_count == 2


def test_warm_search_matches_full_sweep_near_anchor(fcfg):
    """eta_search='warm' around the full-sweep optimum finds the same T*."""
    from repro.core import resource_alloc as ra

    net = dm.sample_network(fcfg, seed=1)
    full = ra.optimize(fcfg, net, "EB", eta_search="coarse")
    warm = ra.optimize(fcfg, net, "EB", eta_search="warm", eta0=full.eta)
    assert warm.T <= full.T * (1 + 1e-9)
    with pytest.raises(ValueError, match="eta0"):
        ra.optimize(fcfg, net, "EB", eta_search="warm")


# ---------------------------------------------------------------------------
# Checkpoint scenario guard
# ---------------------------------------------------------------------------


def test_resume_refuses_different_scenario(run_cfg, stream, tmp_path):
    ckpt = str(tmp_path / "camp")
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    _fresh(run_cfg, scenario="geo-blockfade").run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    with pytest.raises(ValueError, match="scenario"):
        _fresh(run_cfg, scenario="drift").run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    # the same scenario resumes fine
    res = _fresh(run_cfg, scenario="geo-blockfade").run(
        num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    assert [r.round for r in res.records] == [2, 3]


def test_digest_covers_dynamics_params(fcfg):
    """Same scenario name + same large-scale draw but different dynamics
    knobs is a different campaign — the digest must tell them apart (a
    resumed drift walk with another step size would silently diverge)."""
    assert (DriftScenario(step_m=20.0).digest(fcfg, 0)
            != DriftScenario(step_m=50.0).digest(fcfg, 0))
    assert (OutageScenario(prob=0.1).digest(fcfg, 0)
            != OutageScenario(prob=0.3).digest(fcfg, 0))
    assert (HeteroScenario().digest(fcfg, 0)
            != HeteroScenario(f_tiers_hz=(1e9,), p_tiers_dbm=(10.0,))
            .digest(fcfg, 0))


def test_resume_refuses_different_drift_step(run_cfg, stream, tmp_path):
    ckpt = str(tmp_path / "camp")
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    _fresh(run_cfg, scenario=DriftScenario(step_m=20.0)).run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    with pytest.raises(ValueError, match="ls_digest"):
        _fresh(run_cfg, scenario=DriftScenario(step_m=50.0)).run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)


def test_warm_eta_search_usable_from_constructor(run_cfg, stream):
    """eta_search='warm' at construction must not crash: the initial solve
    produces the anchor with a coarse sweep, per-round re-solves warm-start
    off it."""
    exp = _fresh(run_cfg, eta=None, eta_search="warm")
    res = exp.run(num_rounds=1, stream=stream, cohort=COHORT,
                  resample_channel=True, reallocate=True)
    assert res.num_rounds == 1 and np.isfinite(res.records[0].alloc.T)


def test_resume_refuses_different_large_scale_digest(run_cfg, stream,
                                                     tmp_path):
    """Same scenario name, different geometry realisation (area changed) —
    the large-scale digest catches what the name cannot."""
    ckpt = str(tmp_path / "camp")
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    _fresh(run_cfg, scenario="geo-blockfade").run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    other_cfg = RunConfig(
        model=run_cfg.model, shape=run_cfg.shape,
        fedsllm=dataclasses.replace(run_cfg.fedsllm, area_m=1000.0))
    with pytest.raises(ValueError, match="ls_digest"):
        _fresh(other_cfg, scenario="geo-blockfade").run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)


def test_realloc_campaign_resumes_bit_identical(run_cfg, stream, tmp_path):
    """Joint-η campaigns stay pure functions of (RunConfig, seed): resuming
    re-solves each remaining round exactly as the uninterrupted run did (η
    is derived per-round state, so it must not block the resume)."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True,
              reallocate=True)
    full = _fresh(run_cfg, eta=0.2, scenario="geo-blockfade").run(
        num_rounds=4, **kw)

    ckpt = str(tmp_path / "camp")
    _fresh(run_cfg, eta=0.2, scenario="geo-blockfade").run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    rest = _fresh(run_cfg, eta=0.2, scenario="geo-blockfade").run(
        num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    assert [r.round for r in rest.records] == [2, 3]
    for ra_, rb in zip(full.records[2:], rest.records):
        assert ra_.metrics == rb.metrics and ra_.eta == rb.eta
    for a, b in zip(jax.tree.leaves((full.state.lora_c, full.state.lora_s)),
                    jax.tree.leaves((rest.state.lora_c, rest.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Sweep runner
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sweep_res(run_cfg, stream):
    return run_sweep(run_cfg, 2, scenarios=("blockfade", "geo-blockfade"),
                     allocators=("EB", "BA"), stream=stream, cohort=COHORT,
                     exp_overrides={"cut": 1})


def test_sweep_produces_tidy_records(sweep_res):
    assert len(sweep_res.records) == 2 * 2 * 2  # scenarios × allocators × rounds
    for row in sweep_res.records:
        assert {"scenario", "allocator", "round", "eta", "round_time",
                "cumulative_time", "loss_round_start"} <= set(row)
    cell = sweep_res.cell("blockfade", "EB")
    assert [r["round"] for r in cell] == [0, 1]
    summary = sweep_res.summary()
    assert len(summary) == 4
    for row in summary:
        assert row["rounds"] == 2 and row["trace_count"] == 1
        assert row["total_time"] > 0


def test_sweep_delay_reduction_eb_beats_ba(sweep_res):
    """EB (optimised η) must beat BA (η fixed at 0.1) on simulated delay in
    every scenario family — the paper's comparison, per family."""
    red = sweep_res.delay_reduction(allocator="EB", baseline="BA")
    assert set(red) == {"blockfade", "geo-blockfade"}
    for pct in red.values():
        assert 0 < pct < 100


def test_sweep_json_artifact(sweep_res, tmp_path):
    import json

    path = sweep_res.to_json(str(tmp_path / "sweep.json"))
    with open(path) as f:
        payload = json.load(f)
    assert payload["num_rounds"] == 2
    assert len(payload["records"]) == len(sweep_res.records)
    assert payload["summary"]
    red = payload["delay_reduction"]
    assert red["allocator"] == "EB" and red["baseline"] == "BA"
    assert set(red["pct_by_scenario"]) == {"blockfade", "geo-blockfade"}

    # a single-allocator grid has nothing to compare — no fabricated 0%
    from repro.sim.sweep import SweepResult

    solo = SweepResult(records=[], scenarios=("frozen",), allocators=("BA",),
                       num_rounds=0)
    with open(solo.to_json(str(tmp_path / "solo.json"))) as f:
        assert json.load(f)["delay_reduction"] is None


def test_experiment_sweep_classmethod(run_cfg, stream):
    res = Experiment.sweep(run_cfg, num_rounds=1, scenarios=("frozen",),
                           allocators=("BA",), stream=stream, cohort=COHORT,
                           exp_overrides={"cut": 1})
    assert len(res.records) == 1 and res.records[0]["scenario"] == "frozen"
