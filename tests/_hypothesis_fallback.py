"""Minimal drop-in for the ``hypothesis`` API surface this suite uses.

The offline test container cannot install extras, so when the real
``hypothesis`` is absent ``conftest.py`` registers this module (and
sub-module ``strategies``) in ``sys.modules`` *before* test collection.
Property tests then run as seeded random sampling: each ``@given`` test is
executed ``max_examples`` times with boundary values first (lo/hi corners),
then deterministic pseudo-random draws.  No shrinking, no database — the
real hypothesis (installed via ``pip install -e .[test]``, see
pyproject.toml) takes precedence whenever importable.
"""

from __future__ import annotations

import functools
import inspect
import itertools
import random
import sys
import types


class _Strategy:
    def __init__(self, draw, corners=()):
        self._draw = draw
        self.corners = tuple(corners)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     corners=(min_value, max_value))


def floats(min_value: float, max_value: float, allow_nan: bool = False,
           width: int = 64, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     corners=(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements._draw(rng) for _ in range(n)]

    corner = [elements.corners[0] if elements.corners else elements._draw(random.Random(0))
              ] * max(min_size, 1)
    return _Strategy(draw, corners=(corner,))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))],
                     corners=(seq[0], seq[-1]))


class settings:  # noqa: N801 — mirrors hypothesis' public name
    _profiles: dict[str, dict] = {"default": {"max_examples": 20}}
    _current: dict = _profiles["default"]

    def __init__(self, **kw):
        self._kw = kw

    def __call__(self, fn):  # used as @settings(...) decorator
        fn._fallback_settings = self._kw
        return fn

    @classmethod
    def register_profile(cls, name: str, **kw):
        cls._profiles[name] = {"max_examples": kw.get("max_examples", 20)}

    @classmethod
    def load_profile(cls, name: str):
        cls._current = cls._profiles.get(name, cls._profiles["default"])


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            opts = dict(settings._current)
            opts.update(getattr(fn, "_fallback_settings", {}))
            max_examples = int(opts.get("max_examples", 20))
            strats = list(strategies) + list(kw_strategies.values())
            names = list(kw_strategies)
            # boundary examples first (all-lo, all-hi), then random draws
            corner_rows = []
            if all(s.corners for s in strats):
                corner_rows = [[s.corners[0] for s in strats],
                               [s.corners[-1] for s in strats]]
            rng = random.Random(0xFED5)
            for ex in itertools.count():
                if ex >= max_examples:
                    break
                if ex < len(corner_rows):
                    vals = corner_rows[ex]
                else:
                    vals = [s._draw(rng) for s in strats]
                pos = vals[: len(strategies)]
                kws = dict(zip(names, vals[len(strategies):]))
                try:
                    fn(*args, *pos, **kwargs, **kws)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (fallback sampler, example {ex}): "
                        f"{fn.__name__}({pos}, {kws})") from e

        # pytest must not mistake the drawn parameters for fixtures: hide the
        # wrapped signature (the real hypothesis does the same)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_fallback = True
        return wrapper

    return deco


def install() -> types.ModuleType:
    """Register this module as ``hypothesis`` (+``hypothesis.strategies``)."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "sampled_from"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
    return mod
