"""Hierarchical topology subsystem: registry contract, attachment/
localization invariants, per-hop delay composition, per-edge-cell
allocation, two-tier aggregation inside the single-jit-trace contract,
checkpoint topology guards, the star bit-compat golden, and the
topology-dimension sweep."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import Experiment, get_topology, topologies
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.api.allocators import get_allocator
from repro.core import delay_model as dm
from repro.core import fedsllm
from repro.core.resource_alloc import Allocation
from repro.net import allocation
from repro.net.allocation import cell_latency, solve_wait_aware, subnetwork
from repro.net.topology import (EdgeAggTopology, EdgeCloudTopology,
                                HierTopology, RelayTopology, Topology)
from repro.sim import events
from repro.sim.scenario import DriftScenario, get_scenario
from repro.sim.sweep import run_sweep

K = 6
COHORT = 4


@pytest.fixture(scope="module")
def fcfg():
    return FedsLLMConfig(num_clients=K)


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=K))


@pytest.fixture(scope="module")
def stream(run_cfg):
    from repro.data.tokens import TokenStream

    return TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)


def _fresh(run_cfg, **kw):
    kw.setdefault("allocator", "EB")
    kw.setdefault("eta", 0.5)
    return Experiment.from_config(run_cfg, **kw)


# ---------------------------------------------------------------------------
# Registry contract (the fifth axis mirrors the other four)
# ---------------------------------------------------------------------------


def test_topology_registry_contents():
    assert {"star", "edge-cloud", "edge-agg", "relay"} <= set(topologies.names())


def test_unknown_topology_lists_known_names():
    with pytest.raises(KeyError) as exc:
        get_topology("definitely-not-registered")
    for name in topologies.names():
        assert name in str(exc.value)


def test_unknown_topology_in_experiment(run_cfg):
    with pytest.raises(KeyError, match="unknown topology"):
        Experiment.from_config(run_cfg, topology="nope")


def test_get_topology_accepts_instances():
    topo = EdgeCloudTopology(num_edges=4)
    assert get_topology(topo) is topo
    assert isinstance(get_topology("edge-cloud"), EdgeCloudTopology)


def test_topology_parameter_validation():
    with pytest.raises(ValueError, match="num_edges"):
        EdgeCloudTopology(num_edges=0)
    with pytest.raises(ValueError, match="backhaul_bps"):
        RelayTopology(backhaul_bps=0.0)


# ---------------------------------------------------------------------------
# Attachment + localization
# ---------------------------------------------------------------------------


def test_edge_positions_deterministic_ring(fcfg):
    topo = EdgeCloudTopology(num_edges=3)
    exy = topo.edge_xy(fcfg)
    assert exy.shape == (3, 2)
    np.testing.assert_allclose(np.linalg.norm(exy, axis=1), fcfg.area_m / 4.0)
    np.testing.assert_array_equal(exy, topo.edge_xy(fcfg))


def test_attach_picks_nearest_edge(fcfg):
    topo = EdgeCloudTopology(num_edges=3)
    net = get_scenario("geo-blockfade").round_network(fcfg, 0, 0)
    assign = topo.attach(fcfg, net)
    assert assign.shape == (K,)
    d = np.linalg.norm(net.xy[:, None, :] - topo.edge_xy(fcfg)[None], axis=2)
    np.testing.assert_array_equal(assign, np.argmin(d, axis=1))


def test_localize_swaps_distance_term_keeps_shadowing(fcfg):
    """g' = g·10^((pl_bs − pl_edge)/10): the round's shadowing realisation
    survives localization, only the deterministic path loss moves."""
    topo = EdgeCloudTopology(num_edges=2)
    net = get_scenario("geo-blockfade").round_network(fcfg, 0, 1)
    loc, assign = topo.localize(fcfg, net)
    ratio = dm.db_to_lin(net.pl_db - loc.pl_db)
    np.testing.assert_allclose(loc.g_c, net.g_c * ratio, rtol=1e-12)
    np.testing.assert_allclose(loc.g_s, net.g_s * ratio, rtol=1e-12)
    np.testing.assert_array_equal(loc.xy, net.xy)  # geometry untouched
    # edge path loss is the path loss to the attached edge
    exy = topo.edge_xy(fcfg)[assign]
    d_km = np.maximum(np.linalg.norm(net.xy - exy, axis=1), 1.0) / 1000.0
    np.testing.assert_allclose(
        loc.pl_db, fcfg.pathloss_const_db + fcfg.pathloss_exp * np.log10(d_km))


def test_hier_topology_refuses_geometry_free_scenarios(run_cfg):
    """The legacy blockfade/frozen draws carry no positions — attaching to
    an edge is meaningless and must fail loudly."""
    for scenario in ("blockfade", "frozen"):
        with pytest.raises(ValueError, match="geometry"):
            Experiment.from_config(run_cfg, topology="edge-cloud",
                                   scenario=scenario)


def test_drift_reattaches_clients_as_they_move(fcfg):
    """Under mobility the per-round attachment is recomputed from that
    round's geometry — clients hop cells."""
    topo = EdgeCloudTopology(num_edges=3)
    sc = DriftScenario(step_m=150.0)
    assigns = []
    for r in range(6):
        net, assign = events.localized_round_network(
            fcfg, 0, r, scenario=sc, topology=topo)
        assigns.append(assign)
    assert any(not np.array_equal(assigns[0], a) for a in assigns[1:])


def test_localized_round_network_without_topology(fcfg):
    net, assign = events.localized_round_network(
        fcfg, 0, 0, scenario=get_scenario("geo-blockfade"))
    assert assign is None and net.xy is not None


# ---------------------------------------------------------------------------
# Per-hop delay composition
# ---------------------------------------------------------------------------


def test_edge_cloud_timing_adds_cell_backhaul(run_cfg):
    exp = _fresh(run_cfg, topology="edge-cloud", scenario="geo-blockfade")
    topo, assign = exp.topology, exp.assign
    wireless = (exp.timing.total - exp.timing.backhaul)
    counts = np.bincount(assign, minlength=topo.num_edges)
    expect = (counts * exp.fcfg.s_c_bits / topo.backhaul_bps)[assign]
    np.testing.assert_allclose(exp.timing.backhaul, expect, rtol=1e-12)
    np.testing.assert_allclose(
        wireless,
        exp.timing.compute + exp.timing.uplink_fed + exp.timing.uplink_main,
        rtol=1e-12)
    np.testing.assert_array_equal(exp.timing.edge_of, assign)


def test_edge_agg_backhaul_is_one_payload_per_edge(fcfg):
    """Pre-aggregation makes the backhaul load independent of cell size."""
    agg = EdgeAggTopology(num_edges=2, backhaul_bps=1e6)
    cloud = EdgeCloudTopology(num_edges=2, backhaul_bps=1e6)
    assign = np.array([0, 0, 0, 0, 1, 1])
    np.testing.assert_allclose(agg.backhaul_seconds(fcfg, assign, 0.5),
                               np.full(K, fcfg.s_c_bits / 1e6))
    expect = np.where(assign == 0, 4 * fcfg.s_c_bits, 2 * fcfg.s_c_bits) / 1e6
    np.testing.assert_allclose(cloud.backhaul_seconds(fcfg, assign, 0.5),
                               expect)


def test_relay_backhaul_scales_with_local_iterations(fcfg):
    """The relay forwards every local iteration's smashed activations, so
    its hop couples into η through Lemma 2's V(η)."""
    relay = RelayTopology(num_edges=1, backhaul_bps=1e6)
    assign = np.zeros(K, int)
    for eta in (0.3, 0.6):
        V = dm.local_iters(fcfg, eta)
        expect = K * (fcfg.s_c_bits + V * fcfg.s_bits) / 1e6
        np.testing.assert_allclose(relay.backhaul_seconds(fcfg, assign, eta),
                                   np.full(K, expect), rtol=1e-12)
    # more aggressive η (fewer local iters) shrinks the relay hop
    assert (relay.backhaul_seconds(fcfg, assign, 0.6)[0]
            < relay.backhaul_seconds(fcfg, assign, 0.3)[0])


def test_infinite_backhaul_degenerates_to_wireless_only(run_cfg):
    topo = EdgeCloudTopology(num_edges=2, backhaul_bps=np.inf)
    exp = _fresh(run_cfg, topology=topo, scenario="geo-blockfade")
    np.testing.assert_allclose(
        exp.timing.total,
        exp.timing.compute + exp.timing.uplink_fed + exp.timing.uplink_main)
    np.testing.assert_array_equal(exp.timing.backhaul, np.zeros(K))


# ---------------------------------------------------------------------------
# Per-edge-cell allocation
# ---------------------------------------------------------------------------


def test_subnetwork_keeps_full_bandwidth_pool(fcfg):
    net = get_scenario("geo-blockfade").round_network(fcfg, 0, 0)
    sub = subnetwork(net, np.array([1, 3]))
    assert sub.K == 2 and sub.B_c == net.B_c and sub.B_s == net.B_s
    np.testing.assert_array_equal(sub.g_c, net.g_c[[1, 3]])
    np.testing.assert_array_equal(sub.D_k, net.D_k[[1, 3]])


def test_cell_allocation_respects_per_cell_budgets(run_cfg):
    """Each edge owns an independent bandwidth pool: the solved bandwidths
    must fit the budget per cell (not just globally)."""
    exp = _fresh(run_cfg, eta=None, topology="edge-cloud",
                 scenario="geo-blockfade")
    for m in range(exp.topology.num_edges):
        members = exp.assign == m
        if not np.any(members):
            continue
        assert np.sum(exp.alloc.b_c[members]) <= exp.net.B_c * (1 + 1e-6)
        assert np.sum(exp.alloc.b_s[members]) <= exp.net.B_s * (1 + 1e-6)
    assert np.isfinite(exp.alloc.T) and exp.alloc.feasible


def test_proposed_beats_ba_in_every_cell(run_cfg):
    """The paper's 47.63%-style comparison, per edge cell: the per-cell
    Lemma-3 solve + topology-level η sweep must beat the unoptimised BA
    baseline in every non-empty cell."""
    kw = dict(eta=None, topology="edge-cloud", scenario="geo-blockfade")
    prop = _fresh(run_cfg, allocator="proposed", **kw)
    ba = _fresh(run_cfg, allocator="BA", **kw)
    np.testing.assert_array_equal(prop.assign, ba.assign)
    fcfg, topo = prop.fcfg, prop.topology
    T_prop = cell_latency(fcfg, prop.net, prop.alloc, prop.assign, topo,
                          prop.alloc.eta)
    T_ba = cell_latency(fcfg, ba.net, ba.alloc, ba.assign, topo,
                        ba.alloc.eta)
    for m in range(topo.num_edges):
        if np.isnan(T_prop[m]):
            continue
        assert T_prop[m] < T_ba[m], (m, T_prop, T_ba)
    assert prop.alloc.T < ba.alloc.T


# ---------------------------------------------------------------------------
# Two-tier aggregation inside the single-trace contract
# ---------------------------------------------------------------------------


def test_edge_agg_round_matches_flat_weighted_fedavg(run_cfg, stream):
    """Per-edge then cross-edge weighted fedavg == the flat reduction (up to
    float associativity) when weights are the D_k sizes — so edge-side
    pre-aggregation changes the traffic pattern, not the training math."""
    from repro.data.tokens import client_batches

    batches = client_batches(stream, 0, K)
    flat = _fresh(run_cfg, scenario="geo-blockfade")
    tiered = _fresh(run_cfg, scenario="geo-blockfade", topology="edge-agg")
    res_a = flat.run_round(batches)
    res_b = tiered.run_round(batches)
    np.testing.assert_allclose(
        float(res_a.metrics["loss_round_start"]),
        float(res_b.metrics["loss_round_start"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves((res_a.state.lora_c, res_a.state.lora_s)),
                    jax.tree.leaves((res_b.state.lora_c, res_b.state.lora_s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_edge_agg_campaign_single_trace_under_reattachment(run_cfg, stream):
    """The one-hot assignment matrix is a value-only argument: per-round
    re-attachment under mobility must never retrace the round function."""
    exp = _fresh(run_cfg, topology=EdgeAggTopology(num_edges=3),
                 scenario=DriftScenario(step_m=150.0))
    assigns = []
    res = exp.run(num_rounds=3, stream=stream, cohort=COHORT,
                  resample_channel=True,
                  on_round=lambda rec: assigns.append(exp.assign.copy()))
    assert res.num_rounds == 3
    assert exp.trace_count == 1  # the acceptance bar
    assert any(not np.array_equal(assigns[0], a) for a in assigns[1:])


# ---------------------------------------------------------------------------
# star: bit-identical to the pre-topology engine
# ---------------------------------------------------------------------------

# Golden trajectory captured from the pre-topology engine (PR 3 HEAD):
# smoke fedsllm-100m (lora rank 4 / alpha 8), K=6, EB, eta=0.5, cohort 4,
# deadline = 0.7-quantile of the constructor timing, 3 resampled rounds.
GOLDEN_DEADLINE = 110.61189496631023
GOLDEN_LOSSES = (5.556713104248047, 5.560213088989258, 5.551358222961426)
GOLDEN_ROUND_TIMES = (110.61189496631023, 110.61189496631023,
                      104.78746742360255)
GOLDEN_TOTAL_TIME = 326.01125735622304


def test_star_campaign_matches_pre_topology_golden(run_cfg, stream):
    """The default topology IS the legacy engine: simulator quantities
    reproduce the pre-topology trajectory exactly, training losses to float
    tolerance (the golden was captured before repro.net existed)."""
    exp = _fresh(run_cfg)
    assert exp.topology.name == "star" and exp.assign is None
    deadline = float(np.quantile(exp.timing.total, 0.7))
    np.testing.assert_allclose(deadline, GOLDEN_DEADLINE, rtol=1e-12)
    res = exp.run(num_rounds=3, stream=stream, cohort=COHORT,
                  deadline=deadline, resample_channel=True)
    np.testing.assert_allclose([r.round_time for r in res.records],
                               GOLDEN_ROUND_TIMES, rtol=1e-12)
    np.testing.assert_allclose(res.total_time, GOLDEN_TOTAL_TIME, rtol=1e-12)
    np.testing.assert_allclose(res.history("loss_round_start"),
                               GOLDEN_LOSSES, rtol=1e-5)
    assert res.topology == "star" and exp.trace_count == 1


def test_star_explicit_equals_default(run_cfg, stream):
    """Experiment() == Experiment(topology="star"), bit-exact."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    res_a = _fresh(run_cfg).run(num_rounds=2, **kw)
    res_b = _fresh(run_cfg, topology="star").run(num_rounds=2, **kw)
    assert res_a.total_time == res_b.total_time
    for ra_, rb in zip(res_a.records, res_b.records):
        assert ra_.metrics == rb.metrics
    for a, b in zip(jax.tree.leaves((res_a.state.lora_c, res_a.state.lora_s)),
                    jax.tree.leaves((res_b.state.lora_c, res_b.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Joint reallocation + checkpoints on hierarchical graphs
# ---------------------------------------------------------------------------


def test_edge_cloud_realloc_bounded_traces_and_resume(run_cfg, stream,
                                                      tmp_path):
    """The acceptance bar: an edge-cloud campaign with reallocate=True runs
    N rounds with trace_count ≤ len(eta_buckets), and checkpoint-resume is
    bit-identical (per-round re-attachment and per-cell re-solves replay
    exactly)."""
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True,
              reallocate=True)
    mk = lambda: _fresh(run_cfg, eta=0.2, topology="edge-cloud",  # noqa: E731
                        scenario="geo-blockfade")
    exp = mk()
    full = exp.run(num_rounds=4, **kw)
    assert full.num_rounds == 4
    assert exp.trace_count <= len(exp.eta_buckets)
    for rec in full.records:
        assert rec.eta in exp.eta_buckets

    ckpt = str(tmp_path / "camp")
    mk().run(num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    rest = mk().run(num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    assert [r.round for r in rest.records] == [2, 3]
    for ra_, rb in zip(full.records[2:], rest.records):
        assert ra_.metrics == rb.metrics and ra_.eta == rb.eta
    for a, b in zip(jax.tree.leaves((full.state.lora_c, full.state.lora_s)),
                    jax.tree.leaves((rest.state.lora_c, rest.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_refuses_different_topology(run_cfg, stream, tmp_path):
    ckpt = str(tmp_path / "camp")
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    _fresh(run_cfg, topology="edge-cloud", scenario="geo-blockfade").run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    with pytest.raises(ValueError, match="topology"):
        _fresh(run_cfg, topology="star", scenario="geo-blockfade").run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    # the same topology resumes fine
    res = _fresh(run_cfg, topology="edge-cloud", scenario="geo-blockfade").run(
        num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    assert [r.round for r in res.records] == [2, 3]


def test_resume_refuses_different_attachment_digest(run_cfg, stream,
                                                    tmp_path):
    """Same topology name, different graph (edge count) — the attachment
    digest catches what the name cannot."""
    ckpt = str(tmp_path / "camp")
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True)
    _fresh(run_cfg, topology=EdgeCloudTopology(num_edges=2),
           scenario="geo-blockfade").run(
        num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    with pytest.raises(ValueError, match="topo_digest"):
        _fresh(run_cfg, topology=EdgeCloudTopology(num_edges=3),
               scenario="geo-blockfade").run(
            num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)


def test_topology_digest_covers_params(run_cfg, fcfg):
    sc = get_scenario("geo-blockfade")
    assert (EdgeCloudTopology(num_edges=2).digest(fcfg, sc, 0)
            != EdgeCloudTopology(num_edges=3).digest(fcfg, sc, 0))
    assert (EdgeCloudTopology(backhaul_bps=1e6).digest(fcfg, sc, 0)
            != EdgeCloudTopology(backhaul_bps=1e9).digest(fcfg, sc, 0))
    # star's digest is parameter-free and never touches the scenario
    assert (Topology().digest(fcfg, sc, 0)
            == get_topology("star").digest(fcfg, sc, 1))


# ---------------------------------------------------------------------------
# Topology-dimension sweep
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hier_sweep(run_cfg, stream):
    return run_sweep(run_cfg, 2, topologies=("star", "edge-cloud"),
                     scenarios=("geo-blockfade",), allocators=("EB", "BA"),
                     stream=stream, cohort=COHORT, exp_overrides={"cut": 1})


def test_sweep_per_topology_rows(hier_sweep):
    assert len(hier_sweep.records) == 2 * 1 * 2 * 2  # topo × scen × alloc × r
    for row in hier_sweep.records:
        assert row["topology"] in ("star", "edge-cloud")
    summary = hier_sweep.summary()
    assert {(r["topology"], r["allocator"]) for r in summary} == {
        ("star", "EB"), ("star", "BA"),
        ("edge-cloud", "EB"), ("edge-cloud", "BA")}
    for row in summary:
        assert row["rounds"] == 2 and row["total_time"] > 0


def test_sweep_delay_reduction_per_topology(hier_sweep):
    """The paper's comparison, reported per topology: the optimised
    allocator beats BA on the flat graph AND in the hierarchical split."""
    red = hier_sweep.delay_reduction(allocator="EB", baseline="BA")
    assert set(red) == {"star/geo-blockfade", "edge-cloud/geo-blockfade"}
    for pct in red.values():
        assert 0 < pct < 100


def test_sweep_json_records_topologies(hier_sweep, tmp_path):
    import json

    with open(hier_sweep.to_json(str(tmp_path / "hier.json"))) as f:
        payload = json.load(f)
    assert payload["topologies"] == ["star", "edge-cloud"]
    assert set(payload["delay_reduction"]["pct_by_scenario"]) == {
        "star/geo-blockfade", "edge-cloud/geo-blockfade"}


# ---------------------------------------------------------------------------
# Optimised edge placement (kmeans facility location)
# ---------------------------------------------------------------------------


def test_kmeans_placement_is_pure_and_tightens_geometry(fcfg):
    """kmeans places edges at the user geometry's facility-location optimum
    (Lloyd from the ring): a pure function of the drawn geometry, and the
    mean client→edge distance strictly tightens vs the ring."""
    net = get_scenario("geo-blockfade").initial_network(fcfg, seed=0)
    ring = EdgeCloudTopology(num_edges=3, placement="ring")
    km = EdgeCloudTopology(num_edges=3, placement="kmeans")
    exy_a = km.edge_xy(fcfg, net)
    exy_b = km.edge_xy(fcfg, net)
    np.testing.assert_array_equal(exy_a, exy_b)  # deterministic, no RNG

    def mean_dist(topo):
        assign = topo.attach(fcfg, net)
        exy = topo.edge_xy(fcfg, net)[assign]
        return float(np.mean(np.linalg.norm(net.xy - exy, axis=1)))

    assert mean_dist(km) < mean_dist(ring)


def test_kmeans_placement_critical_path_not_worse_than_ring(run_cfg):
    """The per-cell allocation under kmeans placement yields an end-to-end
    critical path (and worst-cell latency) no worse than the deterministic
    ring on geo-blockfade — the whole point of facility location."""
    ring = _fresh(run_cfg, scenario="geo-blockfade",
                  topology=EdgeCloudTopology(num_edges=2, placement="ring"))
    km = _fresh(run_cfg, scenario="geo-blockfade",
                topology=EdgeCloudTopology(num_edges=2, placement="kmeans"))
    assert float(np.max(km.timing.total)) <= float(np.max(ring.timing.total))
    cells_ring = cell_latency(ring.fcfg, ring.net, ring.alloc, ring.assign,
                              ring.topology, ring.eta)
    cells_km = cell_latency(km.fcfg, km.net, km.alloc, km.assign,
                            km.topology, km.eta)
    assert np.nanmax(cells_km) <= np.nanmax(cells_ring)


def test_kmeans_requires_geometry(run_cfg):
    with pytest.raises(ValueError):
        _fresh(run_cfg, scenario="blockfade",
               topology=EdgeCloudTopology(placement="kmeans"))


def test_placement_validation_and_digest(fcfg):
    with pytest.raises(ValueError):
        EdgeCloudTopology(placement="steiner")
    sc = get_scenario("geo-blockfade")
    ring = EdgeCloudTopology(num_edges=2, placement="ring")
    km = EdgeCloudTopology(num_edges=2, placement="kmeans")
    assert ring.digest(fcfg, sc, 0) != km.digest(fcfg, sc, 0)


# ---------------------------------------------------------------------------
# Queueing backhaul (shared metro FIFO / processor sharing) + downlink
# ---------------------------------------------------------------------------


def test_backhaul_model_validation():
    with pytest.raises(ValueError):
        EdgeCloudTopology(backhaul_model="token-ring")


@pytest.mark.parametrize("model", ["fifo", "ps"])
def test_queued_backhaul_composes_nonnegative_hops(fcfg, model):
    """fifo/ps replace the serial pipe: per-client hops are their own
    wait+service in the SHARED metro queue — non-negative, and the composed
    total is wireless + hop exactly."""
    from repro.core import resource_alloc as ra

    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=0)
    for cls in (EdgeCloudTopology, EdgeAggTopology, RelayTopology):
        topo = cls(num_edges=2, backhaul_model=model, backhaul_bps=2e6)
        net, assign = topo.localize(fcfg, net0)
        alloc = topo.allocate(
            fcfg, net, assign,
            lambda f, n, **kw: ra.optimize(f, n, strategy="EB", **kw),
            strategy="EB", eta_search="coarse")
        t = topo.round_timing(fcfg, net, alloc, 0.5, assign)
        assert np.all(np.asarray(t.backhaul) >= -1e-9)
        wireless = fedsllm.simulate_round_time(fcfg, net, alloc, 0.5)
        np.testing.assert_allclose(t.total, wireless.total + t.backhaul)


def test_fifo_backhaul_contends_across_cells(fcfg):
    """Two cells' bursts share ONE metro pipe: tightening the capacity
    must grow someone's queueing wait beyond their own service time —
    contention the serial per-cell pipe cannot represent."""
    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=0)
    topo = EdgeCloudTopology(num_edges=2, backhaul_model="fifo",
                             backhaul_bps=1e3)  # deliberately tight
    net, assign = topo.localize(fcfg, net0)
    totals = np.linspace(1.0, 1.01, fcfg.num_clients)  # near-simultaneous
    hop = topo._queued_backhaul(fcfg, assign, 0.5, totals)
    service = fcfg.s_c_bits / 1e3
    assert float(np.max(hop)) > 1.5 * service  # someone queued behind others


def test_serial_backhaul_stays_default_and_bit_identical(fcfg):
    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=0)
    default = EdgeCloudTopology(num_edges=2)
    assert default.backhaul_model == "serial" and default.downlink_bps == 0.0
    net, assign = default.localize(fcfg, net0)
    legacy = (default._cell_bits(fcfg, assign, 0.5)
              / default.backhaul_bps)[assign]
    np.testing.assert_array_equal(
        default.backhaul_seconds(fcfg, assign, 0.5), legacy)


def test_downlink_broadcast_adds_one_multicast_per_cell(fcfg):
    """downlink_bps > 0 adds ONE broadcast cost — identical for every
    member of a cell — on top of the otherwise-unchanged composition."""
    from repro.core import resource_alloc as ra

    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=0)
    base = EdgeCloudTopology(num_edges=2)
    dl = EdgeCloudTopology(num_edges=2, downlink_bps=1e6)
    net, assign = base.localize(fcfg, net0)
    alloc = ra.optimize(fcfg, net, strategy="EB")
    t_base = base.round_timing(fcfg, net, alloc, 0.5, assign)
    t_dl = dl.round_timing(fcfg, net, alloc, 0.5, assign)
    cost = fcfg.s_c_bits / 1e6
    assert t_base.downlink is None
    np.testing.assert_allclose(t_dl.downlink, cost)
    np.testing.assert_allclose(np.asarray(t_dl.total),
                               np.asarray(t_base.total) + cost)


# ---------------------------------------------------------------------------
# Wait-aware allocation: the allocator↔queueing loop under contended backhaul
# ---------------------------------------------------------------------------

CONTENDED_BPS = 2e3  # two cells' bursts sharing one deliberately thin pipe


def _contended(fcfg, model, **kw):
    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=0)
    topo = EdgeCloudTopology(num_edges=2, backhaul_model=model,
                             backhaul_bps=CONTENDED_BPS, **kw)
    net, assign = topo.localize(fcfg, net0)
    return topo, net, assign


def _blind_solve(fcfg, net, assign, topo, alloc_fn, eta):
    """The wait-blind per-cell solve at one η, priced through the TRUE
    queued round_timing — the pre-loop allocator's answer."""
    cells = [np.where(assign == m)[0] for m in range(topo.num_edges)]
    solved = [(idx, alloc_fn(fcfg, subnetwork(net, idx),
                             eta_grid=np.array([eta])))
              for idx in cells if len(idx)]
    return allocation._combine(fcfg, net, assign, topo, solved, eta,
                               "proposed")


@pytest.mark.parametrize("model", ["fifo", "ps"])
def test_wait_aware_beats_wait_blind_under_contention(fcfg, model):
    """The tentpole acceptance: on a contended fixture (two cells, one thin
    metro pipe) the wait-aware fixed point must return a strictly faster
    end-to-end T than the wait-blind per-cell solve at the same η — both
    priced through the true queued round_timing."""
    topo, net, assign = _contended(fcfg, model)
    alloc_fn = get_allocator("proposed")
    eta = 0.3
    aware, info = solve_wait_aware(fcfg, net, assign, topo, alloc_fn, eta)
    blind = _blind_solve(fcfg, net, assign, topo, alloc_fn, eta)
    assert info.converged and info.iters <= topo.wait_iters
    assert aware is not None and blind is not None
    assert aware.T < blind.T, (aware.T, blind.T)
    # the reported T is exactly the true-queue critical path
    timing = topo.round_timing(fcfg, net, aware, eta, assign)
    I0 = dm.global_rounds(fcfg, eta)
    assert aware.T == pytest.approx(I0 * float(np.max(timing.total)))


def test_wait_aware_allocate_beats_baselines_per_cell():
    """End-to-end through the η sweep: the wait-aware proposed allocate is
    never worse than the wait-blind proposed allocate on the same grid, and
    beats EB/FE/BA in every non-empty cell under the queued pipe.

    The fixture is transmission-bound (small wireless pools) with a
    moderately loaded metro queue: on a compute-bound draw the bandwidth
    split is irrelevant and EB — which sweeps the same η grid — ties the
    exact solver to within queue-arrival epsilon, so per-cell strictness
    would test the channel draw, not the allocator."""
    fcfg = FedsLLMConfig(num_clients=K, bandwidth_total_hz=2e5)
    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=1)
    topo = EdgeCloudTopology(num_edges=2, backhaul_model="fifo",
                             backhaul_bps=2e5, wait_iters=2)
    blind_topo = EdgeCloudTopology(num_edges=2, backhaul_model="fifo",
                                   backhaul_bps=2e5, wait_aware=False)
    net, assign = topo.localize(fcfg, net0)
    prop_fn = get_allocator("proposed")
    kw = dict(strategy="proposed", eta_search="warm", eta0=0.3)
    aware = topo.allocate(fcfg, net, assign, prop_fn, **kw)
    blind = blind_topo.allocate(fcfg, net, assign, prop_fn, **kw)
    assert aware.feasible and blind.feasible
    assert aware.T <= blind.T
    T_aware = cell_latency(fcfg, net, aware, assign, topo, aware.eta)
    for strat in ("EB", "FE", "BA"):
        base = topo.allocate(fcfg, net, assign, get_allocator(strat),
                             strategy=strat, eta_search="warm", eta0=0.3)
        T_base = cell_latency(fcfg, net, base, assign, topo, base.eta)
        for m in range(topo.num_edges):
            if not np.isnan(T_aware[m]):
                assert T_aware[m] < T_base[m], (strat, m, T_aware, T_base)


def test_wait_aware_flag_is_inert_on_serial_backhaul(fcfg):
    """backhaul_model="serial" keeps the legacy allocator bit-identical:
    the loop never engages (no wait_diag) and the flag changes nothing."""
    sc = get_scenario("geo-blockfade")
    net0 = sc.initial_network(fcfg, seed=0)
    prop_fn = get_allocator("proposed")
    allocs = []
    for flag in (True, False):
        topo = EdgeCloudTopology(num_edges=2, wait_aware=flag)
        net, assign = topo.localize(fcfg, net0)
        a = topo.allocate(fcfg, net, assign, prop_fn, strategy="proposed",
                          eta_search="warm", eta0=0.02)
        assert not hasattr(topo, "wait_diag")
        allocs.append(a)
    a, b = allocs
    assert a.T == b.T and a.eta == b.eta
    np.testing.assert_array_equal(a.b_c, b.b_c)
    np.testing.assert_array_equal(a.b_s, b.b_s)
    np.testing.assert_array_equal(a.t_c, b.t_c)
    np.testing.assert_array_equal(a.t_s, b.t_s)


def test_edge_agg_queued_outage_keeps_cell_backhaul_finite(fcfg):
    """Regression (edge-agg × queued × outage): a +inf member must not
    poison its cell's pre-aggregated job — the edge forwards once its
    FINITE members are in; only a fully-dead cell never reaches the
    queue."""
    topo = EdgeAggTopology(num_edges=2, backhaul_model="fifo",
                           backhaul_bps=2e6)
    assign = np.array([0, 0, 0, 1, 1, 1])
    totals = np.array([1.0, 2.0, np.inf, 1.5, 2.5, 3.0])
    arrivals, bits, job_of = topo._backhaul_jobs(fcfg, assign, 0.5, totals)
    np.testing.assert_allclose(arrivals, [2.0, 3.0])  # finite-max per cell
    hop = topo._queued_backhaul(fcfg, assign, 0.5, totals)
    assert np.all(np.isfinite(hop[np.isfinite(totals)]))
    assert hop[2] == 0.0  # the outage'd client never reaches the queue
    # a fully-dead cell never arrives, and doesn't block the live one
    dead = np.array([1.0, 2.0, 3.0, np.inf, np.inf, np.inf])
    arr2, _, _ = topo._backhaul_jobs(fcfg, assign, 0.5, dead)
    np.testing.assert_allclose(arr2, [3.0, np.inf])
    hop2 = topo._queued_backhaul(fcfg, assign, 0.5, dead)
    assert np.all(np.isfinite(hop2[:3])) and np.all(hop2[3:] == 0.0)


def test_combine_prices_critical_path_over_finite_clients(fcfg):
    """Regression (degenerate η sweep under outage): one +inf client must
    not turn every η candidate into T=+inf — the sweep prices the
    deadline-surviving critical path, +inf only when nobody is finite."""
    topo = EdgeCloudTopology(num_edges=2)
    sc = get_scenario("geo-blockfade")
    net, assign = topo.localize(fcfg, sc.initial_network(fcfg, seed=0))

    def cell_alloc(idx, dead=()):
        n = len(idx)
        t_c = np.where(np.isin(idx, list(dead)), np.inf, 1.0)
        return Allocation(1.0, 0.3, 0.5, t_c, np.ones(n),
                          np.full(n, 1e6), np.full(n, 1e6), True, "proposed")

    cells = [np.where(assign == m)[0] for m in range(2)]
    one_dead = [(idx, cell_alloc(idx, dead={int(cells[0][0])}))
                for idx in cells]
    combined = allocation._combine(fcfg, net, assign, topo, one_dead, 0.3,
                                   "proposed")
    assert np.isfinite(combined.T)
    all_dead = [(idx, cell_alloc(idx, dead=set(map(int, idx))))
                for idx in cells]
    degenerate = allocation._combine(fcfg, net, assign, topo, all_dead, 0.3,
                                     "proposed")
    assert np.isinf(degenerate.T)


def test_infeasible_allocation_carries_nan_eta(fcfg):
    bad = allocation._infeasible(fcfg, "proposed")
    assert not bad.feasible and np.isinf(bad.T) and np.isnan(bad.eta)


def test_set_eta_refuses_non_finite(run_cfg):
    exp = _fresh(run_cfg)
    with pytest.raises(ValueError, match="non-finite"):
        exp.set_eta(float("nan"))


def test_realloc_round_refuses_infeasible_solve(run_cfg, monkeypatch):
    """A reallocating round whose solve comes back infeasible must raise
    with the round index instead of adopting a fabricated η."""
    exp = _fresh(run_cfg, topology=EdgeCloudTopology(num_edges=2),
                 scenario="geo-blockfade")
    monkeypatch.setattr(exp.topology, "allocate",
                        lambda *a, **k: allocation._infeasible(exp.fcfg, "EB"))
    with pytest.raises(ValueError, match="round 3"):
        events.round_state(exp, 0, 3, reallocate=True)


HIER_TOPOS = ("edge-cloud", "edge-agg", "relay")
GEO_SCENARIOS = ("geo-blockfade", "drift", "hetero", "outage", "shadowing")


def test_wait_aware_fixed_point_deterministic_on_every_hier_cell(fcfg):
    """Property: on every registered hierarchical topology × geometry
    scenario the wait-aware fixed point at one η converges within its
    deterministic cap and repeat calls are bit-identical — so campaigns
    that re-solve per round stay pure functions of (RunConfig, seed)."""
    prop_fn = get_allocator("proposed")
    eta = 0.3
    for tname in HIER_TOPOS:
        for sname in GEO_SCENARIOS:
            topo = type(get_topology(tname))(num_edges=2,
                                             backhaul_model="fifo")
            net, assign = topo.localize(
                fcfg, get_scenario(sname).round_network(fcfg, 0, 1))
            a1, i1 = solve_wait_aware(fcfg, net, assign, topo, prop_fn, eta)
            a2, i2 = solve_wait_aware(fcfg, net, assign, topo, prop_fn, eta)
            key = (tname, sname)
            assert i1.converged and i1.iters <= topo.wait_iters, (key, i1)
            assert (i1.iters, i1.max_delta) == (i2.iters, i2.max_delta), key
            assert a1 is not None and a1.T == a2.T, key
            np.testing.assert_array_equal(a1.b_c, a2.b_c, err_msg=str(key))
            np.testing.assert_array_equal(a1.t_c, a2.t_c, err_msg=str(key))


def test_wait_aware_realloc_campaign_bounded_traces(run_cfg, stream):
    """A wait-aware reallocating campaign keeps the jit cache η-bucket
    bounded and engages the fixed point every round (diag converged)."""
    exp = _fresh(run_cfg, eta=0.2, allocator="proposed",
                 topology=EdgeCloudTopology(num_edges=2,
                                            backhaul_model="fifo"),
                 scenario="geo-blockfade")
    res = exp.run(num_rounds=2, stream=stream, cohort=COHORT,
                  resample_channel=True, reallocate=True)
    assert res.num_rounds == 2
    assert exp.trace_count <= len(exp.eta_buckets)
    for rec in res.records:
        assert rec.eta in exp.eta_buckets
    diag = exp.topology.wait_diag
    assert diag and all(d.converged for d in diag)


def test_queued_realloc_checkpoint_resume_bit_identical(run_cfg, stream,
                                                        tmp_path):
    """Checkpoint/resume replays a queued-backhaul reallocating campaign
    bit-identically (the queued pricing and the new topology params ride
    the digest)."""
    mk = lambda: _fresh(run_cfg, eta=0.2,  # noqa: E731
                        topology=EdgeCloudTopology(num_edges=2,
                                                   backhaul_model="fifo"),
                        scenario="geo-blockfade")
    kw = dict(stream=stream, cohort=COHORT, resample_channel=True,
              reallocate=True)
    exp = mk()
    full = exp.run(num_rounds=4, **kw)
    assert exp.trace_count <= len(exp.eta_buckets)
    ckpt = str(tmp_path / "camp")
    mk().run(num_rounds=2, checkpoint_dir=ckpt, checkpoint_every=2, **kw)
    rest = mk().run(num_rounds=4, checkpoint_dir=ckpt, resume=True, **kw)
    assert [r.round for r in rest.records] == [2, 3]
    for ra_, rb in zip(full.records[2:], rest.records):
        assert ra_.metrics == rb.metrics and ra_.eta == rb.eta
    for a, b in zip(jax.tree.leaves((full.state.lora_c, full.state.lora_s)),
                    jax.tree.leaves((rest.state.lora_c, rest.state.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
