"""Unified `Experiment` API: registries, config-driven wiring, shim parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Experiment, aggregators, allocators, compressors,
                       get_compressor)
from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                          get_arch, smoke_variant)
from repro.core import federated, fedsllm
from repro.data.tokens import TokenStream, client_batches

CLIENTS = 4


@pytest.fixture(scope="module")
def run_cfg():
    cfg = smoke_variant(get_arch("fedsllm-100m")).replace(
        lora=LoRAConfig(rank=4, alpha=8.0))
    return RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     fedsllm=FedsLLMConfig(num_clients=CLIENTS))


@pytest.fixture(scope="module")
def batches(run_cfg):
    stream = TokenStream(2, 32, run_cfg.model.vocab_size, seed=0)
    return client_batches(stream, 0, CLIENTS)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("registry,expect", [
    (aggregators, {"fedavg", "weighted", "median", "trimmed_mean"}),
    (allocators, {"proposed", "EB", "FE", "BA"}),
    (compressors, {"none", "int8", "randk", "topk"}),
])
def test_registry_contents(registry, expect):
    assert expect <= set(registry.names())


@pytest.mark.parametrize("registry", [aggregators, allocators, compressors])
def test_unknown_strategy_lists_known_names(registry):
    """Mirror `get_arch`: unknown names raise KeyError naming the knowns."""
    with pytest.raises(KeyError) as exc:
        registry.get("definitely-not-registered")
    msg = str(exc.value)
    for name in registry.names():
        assert name in msg


@pytest.mark.parametrize("axis,registry", [
    ("aggregator", aggregators),
    ("allocator", allocators),
    ("compressor", compressors),
])
def test_unknown_strategy_in_experiment(run_cfg, axis, registry):
    """Every strategy axis fails fast at construction, naming the knowns."""
    with pytest.raises(KeyError, match=f"unknown {axis}") as exc:
        Experiment.from_config(run_cfg, **{axis: "nope"})
    for name in registry.names():
        assert name in str(exc.value)


# ---------------------------------------------------------------------------
# Experiment: config -> two rounds
# ---------------------------------------------------------------------------


def test_experiment_two_rounds(run_cfg, batches):
    exp = Experiment.from_config(run_cfg, allocator="EB", eta=0.5)
    assert exp.cohort == CLIENTS
    r1 = exp.run_round(batches)
    r2 = exp.run_round(batches)  # same data: local loss must keep descending
    assert np.isfinite(float(r1.metrics["loss_round_start"]))
    assert float(r2.metrics["loss_round_start"]) < float(r1.metrics["loss_round_start"])
    # co-computed simulated wireless timing, one entry per simulated user
    K = run_cfg.fedsllm.num_clients
    assert r1.timing.total.shape == (K,)
    assert np.all(r1.timing.total > 0) and r1.wall_clock > 0
    # the dead-metric fix: client update norm must be a real, nonzero value
    assert float(r2.metrics["h_c_norm"]) > 0


def test_build_round_fn_contract(run_cfg, batches):
    """build_round_fn (the engine) == Experiment.run_round, bit-exact.

    The former ``make_round_fn`` shim is gone; this pins the contract the
    shim-equivalence test used to enforce directly on the engine: a
    hand-built round function with default aggregation and no codec must
    reproduce the Experiment's round exactly (the Experiment's D_k weights
    are uniform on the even paper split, so weighted == unweighted)."""
    exp = Experiment.from_config(run_cfg, allocator="EB")
    res = exp.run_round(batches)

    state0, _ = fedsllm.init_state(exp.cfg, exp.cut, key=jax.random.PRNGKey(0))
    engine = jax.jit(fedsllm.build_round_fn(exp.cfg, exp.fcfg, exp.cut, exp.eta))
    state1, metrics1 = engine(state0, batches)

    for a, b in zip(jax.tree.leaves((res.state.lora_c, res.state.lora_s)),
                    jax.tree.leaves((state1.lora_c, state1.lora_s))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(res.metrics["loss_round_start"]),
        np.asarray(metrics1["loss_round_start"]))
    assert not hasattr(fedsllm, "make_round_fn")  # deprecation completed


def test_weighted_aggregation_matters(run_cfg, batches):
    """Non-uniform D_k weights must change the aggregated update."""
    exp = Experiment.from_config(run_cfg, allocator="EB")
    skew = np.zeros(CLIENTS)
    skew[0] = 1.0
    exp.net.D_k[:] = CLIENTS * skew + 1e-9  # all mass on client 0
    res_skew = exp.run_round(batches)

    uni = Experiment.from_config(run_cfg, allocator="EB")
    res_uni = uni.run_round(batches)
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(res_skew.state.lora_s), jax.tree.leaves(res_uni.state.lora_s))]
    assert max(diffs) > 0


# ---------------------------------------------------------------------------
# Aggregator strategies
# ---------------------------------------------------------------------------


def _stacked(rows):
    return {"w": jnp.asarray(rows, jnp.float32)}


def test_coordinate_median_ignores_outlier():
    tree = _stacked([[1.0, 1.0], [1.1, 0.9], [0.9, 1.1], [1e6, -1e6]])
    med = aggregators.get("median")(tree)
    np.testing.assert_allclose(np.asarray(med["w"]), [1.0, 1.0], atol=0.11)


def test_trimmed_mean_ignores_outlier():
    tree = _stacked([[1.0, 1.0], [1.1, 0.9], [0.9, 1.1], [1e6, -1e6]])
    tm = aggregators.get("trimmed_mean")(tree)
    assert np.all(np.abs(np.asarray(tm["w"])) < 2.0)


def test_robust_aggregators_respect_mask():
    """A masked-out straggler must not influence the order statistics."""
    tree = _stacked([[1.0], [2.0], [3.0], [1e9]])
    mask = jnp.array([1.0, 1.0, 1.0, 0.0])
    med = aggregators.get("median")(tree, mask=mask)
    np.testing.assert_allclose(np.asarray(med["w"]), [2.0])
    tm = aggregators.get("trimmed_mean")(tree, mask=mask)
    assert float(np.abs(np.asarray(tm["w"]))[0]) < 10.0


def test_fedavg_weighted_matches_manual():
    tree = _stacked([[2.0], [4.0], [6.0], [8.0]])
    w = jnp.array([1.0, 1.0, 2.0, 0.0])
    out = federated.fedavg(tree, weights=w)
    np.testing.assert_allclose(np.asarray(out["w"]), [(2 + 4 + 12) / 4.0])


# ---------------------------------------------------------------------------
# Compressors
# ---------------------------------------------------------------------------


def test_compressor_bits_accounting():
    none, int8 = get_compressor("none"), get_compressor("int8")
    topk = get_compressor("topk", fraction=0.1)
    n = 1 << 16
    assert none.bits(n) == n * 32
    assert int8.bits(n) == n * 8 + 32
    assert topk.bits(n) < 0.2 * n * 32
    assert none.ratio == 1.0 and int8.ratio == 0.25


def test_compressor_rescales_delay_model(run_cfg):
    full = Experiment.from_config(run_cfg, allocator="EB")
    comp = Experiment.from_config(run_cfg, allocator="EB", compressor="int8")
    assert comp.fcfg.s_bits == pytest.approx(0.25 * full.fcfg.s_bits)
    # cheaper uplink -> no-worse optimised latency
    assert comp.alloc.T <= full.alloc.T * (1 + 1e-9)


def test_int8_roundtrip_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    y = get_compressor("int8").apply(x)
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_split_reports_codec_uplink_bits(run_cfg, batches):
    """split_value_and_grad's info reflects the codec's exact uplink volume."""
    from repro.core import lora as lora_lib, split
    from repro.models import transformer as T

    cfg = run_cfg.model
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(0))
    lora, _ = lora_lib.init_lora(params, axes, cfg, key=jax.random.PRNGKey(1))
    lc, ls = lora_lib.split_client_server(lora, 1)
    batch = jax.tree.map(lambda x: x[0], batches)
    _, _, _, dense = split.split_value_and_grad(params, lc, ls, batch, cfg, 1)
    _, _, _, comp = split.split_value_and_grad(params, lc, ls, batch, cfg, 1,
                                              compressor=get_compressor("int8"))
    assert dense["smashed_bits_uplink"] == dense["smashed_bytes"] * 8
    # 8 bits/elem (f32 payload = 4 bytes/elem) + one f32 scale
    assert comp["smashed_bits_uplink"] == dense["smashed_bytes"] * 2 + 32


def test_timing_priced_at_training_eta(run_cfg):
    """RoundResult timing must reflect the η the rounds actually run with."""
    slow = Experiment.from_config(run_cfg, allocator="EB", eta=0.2)
    fast = Experiment.from_config(run_cfg, allocator="EB", eta=0.8)
    # fewer local iterations at larger η -> cheaper simulated round
    assert fast.wall_clock_per_round < slow.wall_clock_per_round


def test_compressed_training_round_stays_finite(run_cfg, batches):
    for codec in ("int8", "randk"):
        exp = Experiment.from_config(run_cfg, allocator="EB", compressor=codec)
        res = exp.run_round(batches)
        assert np.isfinite(float(res.metrics["loss_local_final"]))
        for leaf in jax.tree.leaves(res.state.lora_c):
            assert bool(jnp.all(jnp.isfinite(leaf)))
