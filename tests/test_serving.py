"""Serving: prefill/decode equivalence, ring-buffer local-attention caches,
greedy generation determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, smoke_variant
from repro.models import transformer as T
from repro.serving.decode import decode_tokens


def test_ring_buffer_cache_matches_full_for_local_attention():
    """gemma2-style local layers: ring cache (window slots) must produce the
    same decode logits as a hypothetical full cache (window masks the rest)."""
    cfg = smoke_variant(get_arch("gemma2-9b"))
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks, "mask": jnp.ones((B, S), jnp.float32)}
    logits_full, _ = T.forward(params, batch, cfg)
    cache = T.init_cache(cfg, B, S)
    pre = {"tokens": toks[:, :S - 4], "labels": toks[:, :S - 4]}
    _, cache = T.prefill(params, pre, cfg, cache)
    for i in range(S - 4, S):
        logits_i, cache = T.decode_step(params, toks[:, i:i + 1], cache,
                                        jnp.asarray(i, jnp.int32), cfg)
        np.testing.assert_allclose(np.asarray(logits_i[:, 0]),
                                   np.asarray(logits_full[:, i]),
                                   rtol=3e-2, atol=3e-2)


def test_greedy_generation_deterministic():
    cfg = smoke_variant(get_arch("fedsllm-100m"))
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    o1 = decode_tokens(params, cfg, prompt, 8)
    o2 = decode_tokens(params, cfg, prompt, 8)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert o1.shape == (2, 8)


def test_ssm_decode_state_carries_context():
    """mamba2: decoding after different prefixes yields different logits
    (state actually carries information)."""
    cfg = smoke_variant(get_arch("mamba2-130m"))
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    B, S = 1, 16
    t1 = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    t2 = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    tok = jnp.full((B, 1), 7, jnp.int32)

    def decode_after(prefix):
        cache = T.init_cache(cfg, B, S + 1)
        _, cache = T.prefill(params, {"tokens": prefix, "labels": prefix}, cfg, cache)
        logits, _ = T.decode_step(params, tok, cache, jnp.asarray(S, jnp.int32), cfg)
        return np.asarray(logits)

    l1, l2 = decode_after(t1), decode_after(t2)
    assert not np.allclose(l1, l2)
