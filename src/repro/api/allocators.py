"""Allocator registry — the paper's §IV resource-allocation strategies.

Each entry wraps one branch of ``core.resource_alloc.optimize`` as a named
strategy with the uniform signature

    allocate(fcfg, net, model_params=None, **kw) -> resource_alloc.Allocation

``**kw`` forwards solver knobs (``eta_search``, ``eta_grid``, ``solver``).

Registered strategies (paper Fig. 2 legend):
  proposed  η sweep + exact Lemma-3 bandwidth optimiser (problem (17))
  EB        equal bandwidth per user, optimise η
  FE        fix η = 0.1, optimise bandwidth
  BA        both fixed (the unoptimised baseline)
"""

from __future__ import annotations

from repro.registry import Registry
from repro.core import resource_alloc as ra

allocators: Registry = Registry("allocator")


def _wrap(strategy: str):
    def allocate(fcfg, net, model_params=None, **kw) -> ra.Allocation:
        return ra.optimize(fcfg, net, strategy, model_params=model_params, **kw)

    allocate.__name__ = f"allocate_{strategy}"
    allocate.__doc__ = f"resource_alloc.optimize(..., strategy={strategy!r})"
    return allocate


for _strategy in ("proposed", "EB", "FE", "BA"):
    allocators.register(_strategy)(_wrap(_strategy))


def get_allocator(name: str):
    return allocators.get(name)
