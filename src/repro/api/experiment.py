"""`Experiment` — the one config-driven entry point for FedsLLM runs.

Wires together, from a single frozen ``RunConfig``, everything the loose
factories used to make every caller assemble by hand: model + LoRA init,
the split cut, the jitted Algorithm-1+2 round function, the §IV wireless
channel realisation, the delay-minimisation allocator, and the simulated
round timing.  Strategy axes are pluggable by name through the registries
in this package (``aggregators`` / ``allocators`` / ``compressors``).

    exp = Experiment.from_config(run_cfg, allocator="proposed")
    res = exp.run(num_rounds=20, stream=stream, cohort=8, deadline=5.0)
    res.history("loss_round_start"), res.total_time

Single rounds remain first-class (``run_round``); ``run`` drives the
``repro.sim`` campaign engine — time-varying channels, elastic cohorts,
deadline stragglers — over the same jitted round function.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.aggregators import aggregators
from repro.api.allocators import allocators
from repro.api.compressors import Compressor, get_compressor
from repro.config import (FedsLLMConfig, LoRAConfig, ModelConfig, RunConfig)
from repro.core import delay_model as dm
from repro.core import fedsllm
from repro.core.fedsllm import FedsLLMState, RoundTiming
from repro.core.resource_alloc import Allocation, quantize_eta
from repro.des.schedules import Schedule, get_schedule
from repro.fl.local_algos import LocalAlgo, get_local_algo
from repro.fl.workloads import Workload, get_workload
from repro.net.topology import Topology, get_topology
from repro.pop import Population, get_population


@dataclass
class RoundResult:
    """Everything one global round produces: new state, training metrics and
    the simulated wireless wall-clock the round costs under the allocation."""

    state: FedsLLMState
    metrics: dict[str, Any]
    timing: RoundTiming

    @property
    def wall_clock(self) -> float:
        """Simulated per-round wireless wall-clock (slowest client), seconds."""
        return float(np.max(self.timing.total))


class Experiment:
    """A fully-wired FedsLLM experiment (Algorithms 1+2 + problems (16)/(17)).

    Build with :meth:`from_config`; drive with :meth:`run_round`.  The
    instance owns the mutable training state; ``run_round`` advances it and
    returns the :class:`RoundResult` (the returned state is also the new
    ``exp.state``).
    """

    def __init__(self, cfg: ModelConfig, fcfg: FedsLLMConfig, *,
                 cut: Optional[int] = None, eta: Optional[float] = None,
                 aggregator: str = "weighted", allocator: str = "proposed",
                 compressor: str = "none", compressor_kw: Optional[dict] = None,
                 scenario: Union[str, "Scenario"] = "blockfade",
                 topology: Union[str, Topology] = "star",
                 schedule: Union[str, Schedule] = "sync",
                 local_algo: Union[str, LocalAlgo] = "gd",
                 workload: Union[str, Workload] = "iid",
                 population: Union[str, Population] = "exact",
                 seed: int = 0, remat: bool = False, dp_clip: float = 0.0,
                 dp_noise: float = 0.0, eta_search: str = "coarse",
                 lora_rank: int = 8, key: Optional[jax.Array] = None,
                 net: Optional[dm.Network] = None,
                 alloc: Optional[Allocation] = None):
        from repro.sim.scenario import get_scenario

        if cfg.lora is None:
            cfg = cfg.replace(lora=LoRAConfig(rank=lora_rank))
        self.cfg = cfg
        self.cut = (max(1, int(round(fcfg.split_ratio_min * cfg.num_groups)))
                    if cut is None else cut)

        # --- strategy lookups (fail fast, with the known names) -------------
        self.aggregator_name = aggregator
        self.allocator_name = allocator
        self.compressor_name = compressor
        aggregate = aggregators.get(aggregator)
        allocate = allocators.get(allocator)
        self.compressor: Compressor = get_compressor(compressor,
                                                     **(compressor_kw or {}))
        # the scenario decides how the wireless network evolves across
        # campaign rounds (channel dynamics axis; name or Scenario instance)
        self.scenario = get_scenario(scenario)
        # the topology decides the network *graph* — who talks to whom over
        # which hop (5th axis; ``star`` is the legacy flat graph and leaves
        # every path below bit-identical)
        self.topology = get_topology(topology)
        # the schedule decides how client work and server aggregation
        # interleave across campaign rounds (6th axis; ``sync`` is the
        # round-synchronous default and bit-identical to the pre-schedule
        # engine; ``pipelined``/``async``/``semi-async`` re-time — and for
        # the async family re-order — which client states feed aggregation,
        # all through value-only round-function arguments)
        self.schedule = get_schedule(schedule)
        # the local algorithm decides the client's inner update rule on
        # problem (4) (7th axis; ``gd`` is the paper's plain descent and
        # bit-identical to the pre-registry engine; ``fedprox``/``scaffold``
        # correct for client drift — the stateful scaffold variates live on
        # ``self.algo_state`` and ride the round function as value-only
        # arguments), and the workload decides what data each simulated
        # client sees (``iid`` is the legacy stream; the skew families are
        # the non-IID regimes the correctives exist for)
        self.local_algo = get_local_algo(local_algo)
        self.workload = get_workload(workload)
        # the population model decides how the K simulated clients map onto
        # simulated work (9th axis; ``exact`` is the default and
        # bit-identical — every hook is the identity; ``compact`` gathers
        # each async aggregation onto a fixed (C, …) window; ``meanfield``
        # additionally restricts the event timeline and the per-cell
        # allocator to seeded representatives and prices the FIFO/PS
        # backhaul queues analytically — see ``repro.pop``)
        self.population = get_population(population)
        # campaign engine re-solves (reallocate=True) with the same strategy
        self._allocate = allocate
        self._eta_search = eta_search
        self.seed = seed
        # simulated campaign wall-clock accumulated so far; consecutive
        # run() calls continue it (checkpoint restore overrides it)
        self.campaign_time = 0.0

        # --- channel + allocation: the codec's uplink ratio rescales the
        # paper's s bits before the allocator prices the round.  A caller who
        # already sampled/solved (e.g. to compare strategies) can pass its
        # ``net``/``alloc`` to skip the re-solve. ----------------------------
        self.fcfg = dataclasses.replace(
            fcfg, s_bits=fcfg.s_bits * self.compressor.ratio)
        self.net = (self.scenario.initial_network(self.fcfg, seed)
                    if net is None else net)
        # hierarchical topologies re-anchor the wireless hop on each
        # client's attached edge; ``star`` is the identity (assign=None)
        self.net, self.assign = self.topology.localize(self.fcfg, self.net)
        # 'warm' needs an anchor η that doesn't exist yet at construction:
        # the initial solve runs the coarse sweep to *produce* the anchor,
        # and per-round re-solves (reallocate=True) then warm-start off it
        ctor_search = "coarse" if eta_search == "warm" else eta_search
        self.alloc: Allocation = (
            self.topology.allocate(self.fcfg, self.net, self.assign, allocate,
                                   strategy=allocator, eta_search=ctor_search)
            if alloc is None else alloc)
        if not self.alloc.feasible:
            raise ValueError(
                f"allocator {allocator!r} found no feasible allocation on the "
                f"constructor network (scenario {self.scenario.name!r}, "
                f"topology {self.topology.name!r}) — an infeasible Allocation "
                f"has eta=nan and cannot price an experiment")
        # η* prices the allocation; the training η is clamped so Lemma 2
        # still yields a non-trivial local-iteration count
        self.eta = (min(float(self.alloc.eta), self.fcfg.eta_train_max)
                    if eta is None else float(eta))
        # anchor of the 'warm' per-round η re-solve window: the η* the
        # constructor solve produced (NOT the clamped training η, and NOT
        # chained round-to-round) — fixed at construction so a resumed
        # campaign re-solves exactly what the uninterrupted one did
        self._eta0 = float(self.alloc.eta)
        # per-round wall-clock at the η the rounds actually train with
        # (I0/V/τ recomputed at self.eta; t_c/t_s from the allocation;
        # hierarchical topologies add the backhaul hop of each client's path)
        self.timing: RoundTiming = self.topology.round_timing(
            self.fcfg, self.net, self.alloc, self.eta, self.assign)

        # --- model + split + jitted round functions -------------------------
        key = jax.random.PRNGKey(seed) if key is None else key
        self.state, self._axes = fedsllm.init_state(cfg, self.cut, key=key)
        # everything build_round_fn needs besides η — kept so set_eta can
        # build additional per-η round functions with identical semantics
        self._round_fn_kw = dict(
            remat=remat, dp_clip=dp_clip, dp_noise=dp_noise,
            aggregator=aggregate,
            compressor=(None if compressor == "none" else self.compressor),
            dp_seed=seed, two_tier=self.topology.two_tier,
            local_algo=self.local_algo)
        # stateful local algorithms (scaffold) carry per-client round-fn
        # state across rounds: (K, …)-stacked variates shaped like the
        # global LoRA pair, advanced by run_round, checkpointed by campaigns
        self.algo_state = self.local_algo.init_variates(
            (self.state.lora_c, self.state.lora_s), self.fcfg.num_clients)
        # per-η cache: η is trace-affecting (Lemma 2's local-iteration count
        # is a scan length), so joint per-round reallocation would recompile
        # every round without it.  trace_count sums traces across ALL cached
        # functions — a campaign must keep it ≤ the number of η buckets.
        self._traces = 0
        self._round_fns: dict[float, Any] = {}
        self._round_fn = self._round_fn_for(self.eta)

    # ------------------------------------------------------------------

    @classmethod
    def from_config(cls, run_cfg: RunConfig, **overrides) -> "Experiment":
        """Wire an experiment from a frozen :class:`RunConfig`.

        ``run_cfg.model`` supplies the architecture (a default LoRA config is
        attached if absent), ``run_cfg.fedsllm`` the §IV system model (paper
        defaults if absent) and ``run_cfg.train.seed`` the seed.
        ``scenario=`` selects the channel-dynamics family by name (or takes a
        ``repro.sim.scenario.Scenario`` instance); the default ``blockfade``
        keeps the pre-scenario semantics bit-identical.  ``topology=``
        selects the network graph (``repro.net.topology``): ``star`` (the
        flat default, bit-identical to the pre-topology engine) |
        ``edge-cloud`` | ``edge-agg`` | ``relay`` — non-star topologies
        need a geometry-carrying scenario (e.g. ``geo-blockfade``).
        ``schedule=`` selects the execution discipline
        (``repro.des.schedules``): ``sync`` (the round-synchronous default,
        bit-identical to the pre-schedule engine) | ``pipelined`` |
        ``async`` | ``semi-async``.
        ``local_algo=`` selects the client local-update rule
        (``repro.fl.local_algos``): ``gd`` (the paper's plain descent,
        bit-identical to the pre-registry engine) | ``fedprox`` |
        ``scaffold``; ``workload=`` the per-client data distribution
        (``repro.fl.workloads``): ``iid`` (the legacy stream semantics) |
        ``quantity-skew`` | ``length-skew`` | ``dirichlet``.
        ``population=`` selects the client-population model
        (``repro.pop``): ``exact`` (the default, bit-identical) |
        ``compact`` (fixed-window O(cohort) device batches under async
        schedules) | ``meanfield`` (plus representative timelines and
        analytic queue pricing — the mega-scale regime).
        ``run_cfg.shape`` is *not* consumed here: batch geometry comes from
        the ``batches`` pytree handed to :meth:`run_round` (shape configs
        drive the data-stream construction at call sites).  Keyword
        ``overrides`` go to ``__init__`` (e.g. ``aggregator="median"``;
        ``remat=True`` is an explicit opt-in, not inherited from
        ``train.remat``, so the round stays bit-identical to the shim path).
        """
        fcfg = run_cfg.fedsllm if run_cfg.fedsllm is not None else FedsLLMConfig()
        overrides.setdefault("seed", run_cfg.train.seed)
        return cls(run_cfg.model, fcfg, **overrides)

    # ------------------------------------------------------------------
    # per-η jitted round functions

    def _round_fn_for(self, eta: float):
        """The jitted round function for a training η (build+cache on miss).

        The cache key is the exact η the function was built with; callers
        that adopt a *solved* η* go through :meth:`set_eta`, which quantizes
        onto the ``fcfg.eta_bucket`` grid first so the number of distinct
        traces a campaign can accumulate is bounded by the bucket count.
        """
        key = round(float(eta), 10)
        fn = self._round_fns.get(key)
        if fn is None:
            raw = fedsllm.build_round_fn(self.cfg, self.fcfg, self.cut, eta,
                                         **self._round_fn_kw)

            # trace-counting wrapper: bumps only when jit (re)traces, so
            # campaigns can assert they never recompile across rounds
            if self.local_algo.stateful:
                def _counted_round_fn(state, batches, mask, key, weights,
                                      assign=None, update_scale=None,
                                      algo_state=None, algo_ids=None):
                    self._traces += 1
                    return raw(state, batches, mask, key, weights, assign,
                               update_scale, algo_state, algo_ids)
            else:
                def _counted_round_fn(state, batches, mask, key, weights,
                                      assign=None, update_scale=None):
                    self._traces += 1
                    return raw(state, batches, mask, key, weights, assign,
                               update_scale)

            fn = jax.jit(_counted_round_fn)
            self._round_fns[key] = fn
        return fn

    def set_eta(self, eta: float) -> float:
        """Adopt a new training η (quantized), switching the round function.

        ``eta`` — typically a freshly solved η* — is snapped onto the
        ``fcfg.eta_bucket`` grid and clamped to ``fcfg.eta_train_max``; the
        matching jitted round function is fetched from the per-η cache (built
        on first use).  Returns the η actually adopted.  This is how
        ``reallocate=True`` campaigns re-solve Lemma 1/2 jointly every round
        while keeping ``trace_count`` ≤ the number of η buckets.

        Non-finite η is rejected loudly: an infeasible Allocation carries
        ``eta=nan``, and silently adopting a fabricated η would train the
        campaign on a round the allocator could not actually solve.
        """
        if not np.isfinite(eta):
            raise ValueError(
                f"cannot adopt non-finite eta {eta!r} — an infeasible "
                f"allocation has no solved η* (see allocation._infeasible)")
        q = quantize_eta(eta, self.fcfg.eta_bucket, self.fcfg.eta_train_max)
        if q != self.eta:
            self.eta = q
            self._round_fn = self._round_fn_for(q)
        return q

    def reprice_timing(self) -> RoundTiming:
        """Re-price the simulated round timing at the current (net, alloc, η).

        The campaign engine calls this after every per-round channel/η
        update; standalone callers that mutate ``net``/``alloc`` or call
        :meth:`set_eta` directly should too, so ``wall_clock_per_round``
        reflects what the rounds actually cost.  Hierarchical topologies
        compose the backhaul hop into every client's critical path.
        """
        self.timing = self.topology.round_timing(self.fcfg, self.net,
                                                 self.alloc, self.eta,
                                                 self.assign)
        return self.timing

    @property
    def eta_buckets(self) -> list[float]:
        """The η values with a built round function (≈ compile cache keys)."""
        return sorted(self._round_fns)

    # ------------------------------------------------------------------

    @property
    def cohort(self) -> int:
        """Clients trained per round (= the simulated radio population K)."""
        return self.fcfg.num_clients

    @property
    def round_fn(self):
        """The underlying jitted round function (for benchmarking/inspection)."""
        return self._round_fn

    @property
    def trace_count(self) -> int:
        """Total traces (≈ compiles) across all cached round functions.

        A fixed-η campaign must keep this at 1: per-round masks, weights and
        batches vary only in value, never in structure.  A joint-η campaign
        (``reallocate=True``) must keep it ≤ the number of η buckets
        (``len(eta_buckets)``) — each bucket compiles at most once."""
        return self._traces

    @property
    def wall_clock_per_round(self) -> float:
        """Simulated wireless wall-clock of one global round (slowest client,
        seconds), at the η the rounds actually train with."""
        return float(np.max(self.timing.total))

    def client_weights(self, num_clients: int) -> jax.Array:
        """Aggregation weights D_k for a cohort of the first ``num_clients``
        simulated users (the paper's data-size-weighted FedAvg)."""
        return jnp.asarray(self.net.D_k[:num_clients], jnp.float32)

    def run_round(self, batches, key: Optional[jax.Array] = None,
                  mask: Optional[jax.Array] = None,
                  client_ids: Optional[np.ndarray] = None,
                  weight_scale: Optional[np.ndarray] = None,
                  update_scale: Optional[float] = None) -> RoundResult:
        """One global round: train (Algorithms 1+2) + simulated wall-clock.

        ``batches``: pytree with leaves stacked ``(C, ...)``, one slice per
        cohort client.  ``mask``: optional ``(C,)`` survivor mask.
        ``client_ids``: which simulated users this cohort is (aggregation
        weights become their ``D_k``); default: the first ``C`` users.
        ``weight_scale``: optional ``(C,)`` multiplier on the D_k weights —
        the async schedules' relative staleness discount ``1/(1+s)^β``
        rides here, a value-only argument like the mask (no retrace).
        ``update_scale``: optional scalar server mixing rate α on the
        aggregated update (Δw ← Δw + α·h̄) — the async schedules' ABSOLUTE
        staleness damping (a normalized weighted mean cancels any common
        per-client discount, so damping must scale the update itself).
        ``key``: optional PRNG key for the DP noise; when None, a per-round
        key is derived inside the trace from the experiment seed and the
        global round counter (so noise never repeats across rounds).

        Under a two-tier topology (``edge-agg``) the cohort's one-hot
        client→edge membership rides along as a value-only argument, so the
        per-edge aggregation tracks re-attachment without retracing.
        """
        C = jax.tree.leaves(batches)[0].shape[0]
        ids = (np.arange(C) if client_ids is None
               else np.asarray(client_ids))
        if client_ids is None:
            weights = self.client_weights(C)
        else:
            weights = jnp.asarray(self.net.D_k[ids], jnp.float32)
        if weight_scale is not None:
            weights = weights * jnp.asarray(weight_scale, jnp.float32)
        assign = None
        if self.topology.two_tier and self.assign is not None:
            M = self.topology.num_edges
            assign = jnp.asarray(
                np.eye(M, dtype=np.float32)[np.asarray(self.assign)[ids]])
        scale = (None if update_scale is None
                 else jnp.asarray(update_scale, jnp.float32))
        if self.local_algo.stateful:
            # cohort→population row map for the variates: value-only, so
            # elastic cohorts reuse the same trace
            algo_ids = jnp.asarray(ids, jnp.int32)
            self.state, metrics, self.algo_state = self._round_fn(
                self.state, batches, mask, key, weights, assign, scale,
                self.algo_state, algo_ids)
        else:
            self.state, metrics = self._round_fn(self.state, batches, mask,
                                                 key, weights, assign, scale)
        return RoundResult(self.state, metrics, self.timing)

    def run(self, num_rounds: Optional[int] = None, **kwargs) -> "CampaignResult":
        """Run a multi-round campaign (the ``repro.sim`` engine).

        Per-round channel re-sampling (``resample_channel=True``, optionally
        ``reallocate=True``), elastic cohorts (``cohort=``), deadline
        straggler masks (``deadline=`` seconds), Lemma-1 stopping
        (``stop_at_lemma1=True``) and periodic checkpointing
        (``checkpoint_dir=``/``checkpoint_every=``/``resume=``).  Data comes
        from exactly one of ``stream=``/``batches=``/``batches_fn=``; see
        :func:`repro.sim.campaign.run_campaign` for the full contract.

        ``num_rounds`` is the campaign's absolute length — rounds run from
        the state's current global round counter, so consecutive ``run``
        calls continue the same scenario rather than replaying it.  On a
        fresh experiment, ``run(num_rounds=1, resample_channel=False,
        batches=b)`` is bit-identical to ``run_round(b)``; the whole
        campaign reuses one jit trace of the round function
        (``trace_count`` stays at 1).
        """
        from repro.sim.campaign import run_campaign

        return run_campaign(self, num_rounds, **kwargs)

    @classmethod
    def sweep(cls, run_cfg: RunConfig, **kwargs) -> "SweepResult":
        """Fan a grid of scenarios × allocators into one tidy records table.

        Builds one experiment per (scenario, allocator) cell from the same
        ``RunConfig``, runs the same campaign through each, and returns a
        :class:`repro.sim.sweep.SweepResult` — long-format per-round records
        plus per-cell summaries and the paper's delay-reduction comparison
        (``proposed`` vs ``BA``) per scenario family.  See
        :func:`repro.sim.sweep.run_sweep` for the full contract.

            res = Experiment.sweep(run_cfg, num_rounds=10, stream=stream,
                                   scenarios=("blockfade", "geo-blockfade"),
                                   allocators=("proposed", "BA"))
            res.summary(), res.delay_reduction()
        """
        from repro.sim.sweep import run_sweep

        return run_sweep(run_cfg, **kwargs)

    def describe(self) -> str:
        from repro.core.lora import lora_param_count

        return (f"Experiment[{self.cfg.name}] cut={self.cut}/{self.cfg.num_groups} "
                f"lora={lora_param_count(self.cfg)/1e6:.2f}M "
                f"agg={self.aggregator_name} alloc={self.allocator_name} "
                f"codec={self.compressor_name} scenario={self.scenario.name} "
                f"topo={self.topology.name} sched={self.schedule.name} "
                f"algo={self.local_algo.name} workload={self.workload.name} "
                f"pop={self.population.name} "
                f"T*={self.alloc.T:.1f}s η*={self.alloc.eta:.2f} "
                f"round={float(np.max(self.timing.total)):.2f}s")
