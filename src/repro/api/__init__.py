"""Unified FedsLLM experiment API.

One config-driven entry point replaces the loose function factories that
every launcher, example and benchmark used to re-wire by hand:

    from repro.api import Experiment
    from repro.config import RunConfig, SHAPES, get_arch

    run_cfg = RunConfig(model=get_arch("fedsllm-100m"), shape=SHAPES["train_4k"])
    exp = Experiment.from_config(run_cfg)          # model+LoRA+split+channel+allocator
    res = exp.run_round(batches)                   # one Algorithm-1+2 global round
    res.metrics, res.timing.total                  # training + simulated wall-clock

    camp = exp.run(num_rounds=20, stream=stream,   # multi-round campaign:
                   cohort=8, deadline=5.0)         # fading + cohorts + stragglers
    camp.history("loss_round_start"), camp.total_time

Seven pluggable strategy axes, each a named registry (mirroring
``config.register_arch`` — unknown names raise ``KeyError`` listing the
known ones):

  ``aggregators``  fed-server reduction: ``fedavg`` | ``weighted`` (D_k) |
                   ``median`` | ``trimmed_mean``  (mask/straggler-aware)
  ``allocators``   §IV delay-minimisation strategies: ``proposed`` | ``EB`` |
                   ``FE`` | ``BA``
  ``compressors``  smashed-activation uplink codecs: ``none`` | ``int8`` |
                   ``randk`` | ``topk`` — the codec's ratio rescales the
                   delay model's ``s`` bits and its quantisation error flows
                   through training (straight-through; ``int8``/``randk``
                   are the stable in-loop choices, see the module docstring)
  ``scenarios``    channel dynamics across campaign rounds: ``frozen`` |
                   ``blockfade`` (default, the legacy bit-frozen semantics) |
                   ``geo-blockfade`` | ``drift`` | ``hetero`` | ``outage`` |
                   ``shadowing`` (AR(1)-correlated) — each splits the
                   once-per-campaign large-scale state from per-round
                   fading (``repro.sim.scenario``)
  ``topologies``   the network graph: ``star`` (default, the legacy flat
                   FedsLLM graph, bit-identical) | ``edge-cloud`` |
                   ``edge-agg`` | ``relay`` — multi-hop client→edge→cloud
                   splits with per-hop delay composition and per-edge-cell
                   resource allocation (``repro.net.topology``)
  ``schedules``    the execution discipline: ``sync`` (default, the
                   round-synchronous engine, bit-identical) | ``pipelined``
                   (microbatch overlap across the wireless split) |
                   ``async`` | ``semi-async`` (no round barrier — clients
                   rejoin on completion, arrivals aggregate
                   staleness-weighted; ``repro.des.schedules``)
  ``local_algos``  the client local-update rule on problem (4): ``gd``
                   (default, the paper's plain descent, bit-identical) |
                   ``fedprox`` (proximal pull to the broadcast state) |
                   ``scaffold`` (control-variate-corrected steps with
                   per-client variates carried across rounds and
                   checkpointed; ``repro.fl.local_algos``)

Data heterogeneity is a first-class *workload* on the same footing
(``repro.fl.workloads``): ``iid`` (default, the legacy stream semantics) |
``quantity-skew`` | ``length-skew`` | ``dirichlet`` domain skew — the
non-IID client-drift regimes where the local algorithms (and aggregators,
schedules) actually separate.

The client *population* model is the 9th axis (``repro.pop``): ``exact``
(default, every simulated client materialised — bit-identical) |
``compact`` (async rounds gather arrivals into a fixed-size window, so
device cost per round is O(cohort) not O(K)) | ``meanfield`` (compact
windows plus analytic queue pricing and representative-client allocation
— the 10⁵-client campaign regime).

``Experiment.sweep`` fans a grid of topologies × scenarios × allocators ×
schedules × local algorithms × workloads × populations into one tidy
records table (``repro.sim.sweep``) for cross-family comparisons.
"""

from repro.api.aggregators import aggregators, get_aggregator
from repro.api.allocators import allocators, get_allocator
from repro.api.compressors import Compressor, compressors, get_compressor
from repro.api.experiment import Experiment, RoundResult
from repro.des.schedules import Schedule, get_schedule, schedules
from repro.fl.local_algos import LocalAlgo, get_local_algo, local_algos
from repro.fl.workloads import Workload, get_workload, workloads
from repro.net.topology import Topology, get_topology, topologies
from repro.pop import Population, get_population, populations
from repro.registry import Registry
from repro.sim.campaign import CampaignResult, RoundRecord
from repro.sim.scenario import Scenario, get_scenario, scenarios
from repro.sim.sweep import SweepResult, run_sweep

__all__ = [
    "Experiment", "RoundResult", "Registry",
    "CampaignResult", "RoundRecord",
    "SweepResult", "run_sweep",
    "aggregators", "get_aggregator",
    "allocators", "get_allocator",
    "compressors", "get_compressor", "Compressor",
    "scenarios", "get_scenario", "Scenario",
    "topologies", "get_topology", "Topology",
    "schedules", "get_schedule", "Schedule",
    "local_algos", "get_local_algo", "LocalAlgo",
    "workloads", "get_workload", "Workload",
    "populations", "get_population", "Population",
]
