"""Unified FedsLLM experiment API.

One config-driven entry point replaces the loose function factories that
every launcher, example and benchmark used to re-wire by hand:

    from repro.api import Experiment
    from repro.config import RunConfig, SHAPES, get_arch

    run_cfg = RunConfig(model=get_arch("fedsllm-100m"), shape=SHAPES["train_4k"])
    exp = Experiment.from_config(run_cfg)          # model+LoRA+split+channel+allocator
    res = exp.run_round(batches)                   # one Algorithm-1+2 global round
    res.metrics, res.timing.total                  # training + simulated wall-clock

    camp = exp.run(num_rounds=20, stream=stream,   # multi-round campaign:
                   cohort=8, deadline=5.0)         # fading + cohorts + stragglers
    camp.history("loss_round_start"), camp.total_time

Three pluggable strategy axes, each a named registry (mirroring
``config.register_arch`` — unknown names raise ``KeyError`` listing the
known ones):

  ``aggregators``  fed-server reduction: ``fedavg`` | ``weighted`` (D_k) |
                   ``median`` | ``trimmed_mean``  (mask/straggler-aware)
  ``allocators``   §IV delay-minimisation strategies: ``proposed`` | ``EB`` |
                   ``FE`` | ``BA``
  ``compressors``  smashed-activation uplink codecs: ``none`` | ``int8`` |
                   ``randk`` | ``topk`` — the codec's ratio rescales the
                   delay model's ``s`` bits and its quantisation error flows
                   through training (straight-through; ``int8``/``randk``
                   are the stable in-loop choices, see the module docstring)

``core.fedsllm.make_round_fn`` remains as a deprecated shim over the same
engine (``build_round_fn``) and produces bit-identical rounds; new code
should construct an :class:`Experiment` instead.
"""

from repro.api.aggregators import aggregators, get_aggregator
from repro.api.allocators import allocators, get_allocator
from repro.api.compressors import Compressor, compressors, get_compressor
from repro.api.experiment import Experiment, RoundResult
from repro.api.registry import Registry
from repro.sim.campaign import CampaignResult, RoundRecord

__all__ = [
    "Experiment", "RoundResult", "Registry",
    "CampaignResult", "RoundRecord",
    "aggregators", "get_aggregator",
    "allocators", "get_allocator",
    "compressors", "get_compressor", "Compressor",
]
