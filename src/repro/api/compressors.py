"""Uplink compressor registry — codecs for the smashed-activation uplink.

The paper charges a fixed ``s`` bits per smashed-activation upload (eq. (15)
via ``FedsLLMConfig.s_bits``).  A ``Compressor`` makes that volume a property
of the chosen codec: ``Experiment`` rescales ``s_bits`` by the codec's
``ratio`` before running the allocator (so the delay model sees the smaller
uplink), and the split engine applies the codec to the activations
straight-through (``core.split.split_value_and_grad(compressor=...)``), so
training sees the codec's quantisation error too.

Entries are *factories*: ``get_compressor("topk", fraction=0.05)`` builds a
configured instance.

Registered codecs:
  none   identity (paper-faithful, ratio 1)
  int8   per-tensor absmax int8 quantisation (ratio 8/32 vs float32) — the
         recommended lossy activation codec
  randk  fixed pseudorandom coordinate subsampling (seed-reproducible, so no
         index bits on the wire).  The mask is constant across local
         iterations, making the codec a *linear* channel — FEDL's surrogate
         ∇F_k(Δw+h) − ∇F_k(Δw) stays consistent and local GD is stable.
  topk   magnitude top-k sparsification, values + packed indices.  WARNING:
         the data-dependent mask flips between local iterations, which
         breaks the surrogate's gradient-difference cancellation and can
         diverge local GD (observed on smoke configs).  Appropriate for
         one-shot update uploads, not the inner training loop.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import jax

from repro.registry import Registry
from repro.core import compression


@runtime_checkable
class Compressor(Protocol):
    """Lossy straight-through codec for device arrays on the uplink."""

    name: str

    def apply(self, x: jax.Array) -> jax.Array:
        """Compress→decompress round-trip (jit-traceable, shape-preserving)."""
        ...

    def bits(self, nelems: int, dense_bits: int = 32) -> float:
        """Uplink volume in bits for a tensor of ``nelems`` elements."""
        ...

    @property
    def ratio(self) -> float:
        """Nominal compressed/dense volume ratio, used to rescale the delay
        model's ``s_bits`` before the allocator runs."""
        ...


compressors: Registry = Registry("compressor")

# nominal tensor size used to price top-k index bits in ``ratio`` (the exact
# per-tensor volume comes from ``bits`` at trace time)
_NOMINAL_ELEMS = 1 << 20


@compressors.register("none")
@dataclass(frozen=True)
class NoneCompressor:
    """Identity codec — the paper's uncompressed uplink."""

    name: str = "none"

    def apply(self, x: jax.Array) -> jax.Array:
        return x

    def bits(self, nelems: int, dense_bits: int = 32) -> float:
        return float(nelems * dense_bits)

    @property
    def ratio(self) -> float:
        return 1.0


@compressors.register("int8")
@dataclass(frozen=True)
class Int8Compressor:
    """Per-tensor absmax int8 quantisation (8 value bits + one f32 scale)."""

    name: str = "int8"

    def apply(self, x: jax.Array) -> jax.Array:
        q, scale = compression.quantize_int8(x)
        return compression.dequantize_int8(q, scale, dtype=x.dtype)

    def bits(self, nelems: int, dense_bits: int = 32) -> float:
        return float(nelems * 8 + 32)

    @property
    def ratio(self) -> float:
        return 8.0 / 32.0


@compressors.register("randk")
@dataclass(frozen=True)
class RandKCompressor:
    """Fixed pseudorandom keep-``fraction`` coordinate mask.

    Both ends derive the mask from the shared ``seed``, so only the kept
    values travel (no index bits).  Because the mask is data-independent and
    constant across local iterations, the codec is a fixed linear projection
    — safe inside FEDL's local GD loop, unlike ``topk``."""

    fraction: float = 0.5
    seed: int = 0
    value_bits: int = 32
    name: str = "randk"

    def apply(self, x: jax.Array) -> jax.Array:
        mask = jax.random.bernoulli(jax.random.PRNGKey(self.seed),
                                    self.fraction, x.shape)
        return x * mask.astype(x.dtype)

    def bits(self, nelems: int, dense_bits: int = 32) -> float:
        k = max(1, int(math.ceil(self.fraction * nelems)))
        return float(k * self.value_bits + 32)  # values + the shared seed

    @property
    def ratio(self) -> float:
        return self.fraction * self.value_bits / 32.0


@compressors.register("topk")
@dataclass(frozen=True)
class TopKCompressor:
    """Keep the top-``fraction`` entries by magnitude; charge value+index bits.

    WARNING: data-dependent masking is discontinuous across local iterations
    and can diverge FEDL's local GD when used on activations (see module
    docstring); prefer ``int8``/``randk`` there."""

    fraction: float = 0.1
    value_bits: int = 32
    name: str = "topk"

    def apply(self, x: jax.Array) -> jax.Array:
        return x * compression.topk_mask(x, self.fraction)

    def bits(self, nelems: int, dense_bits: int = 32) -> float:
        k = max(1, int(math.ceil(self.fraction * nelems)))
        index_bits = max(1, math.ceil(math.log2(max(nelems, 2))))
        return float(k * (self.value_bits + index_bits))

    @property
    def ratio(self) -> float:
        return self.bits(_NOMINAL_ELEMS) / (_NOMINAL_ELEMS * 32.0)


def get_compressor(name: str, **kw) -> Compressor:
    """Build a configured codec: ``get_compressor("topk", fraction=0.05)``."""
    return compressors.get(name)(**kw)
