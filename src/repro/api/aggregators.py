"""Aggregator registry — the fed-server reduction of Algorithm 1.

Every aggregator has the uniform signature

    aggregate(stacked, weights=None, mask=None) -> tree

where ``stacked`` is a pytree with leaves ``(K, ...)``, ``weights`` is an
optional ``(K,)`` array (e.g. client data sizes D_k for the paper's weighted
FedAvg) and ``mask`` is an optional ``(K,)`` 0/1 survivor mask (straggler
tolerance).  All entries are built on ``core.federated``'s pytree machinery.

Registered strategies:
  fedavg        uniform mean (paper Algorithm 1 as written; ignores weights)
  weighted      D_k-weighted FedAvg (paper's data-size weighting)
  median        coordinate-wise median, mask-aware (robust)
  trimmed_mean  coordinate-wise β-trimmed mean, mask-aware (robust)
"""

from __future__ import annotations

from repro.registry import Registry
from repro.core import federated

aggregators: Registry = Registry("aggregator")


@aggregators.register("fedavg")
def _fedavg_uniform(stacked, weights=None, mask=None):
    """Uniform FedAvg — Algorithm 1's (1/K)·Σ, weights intentionally ignored."""
    return federated.fedavg(stacked, mask=mask)


@aggregators.register("weighted")
def _fedavg_weighted(stacked, weights=None, mask=None):
    """Data-size-weighted FedAvg: Σ D_k·h_k / Σ D_k (uniform if weights=None)."""
    return federated.fedavg(stacked, weights=weights, mask=mask)


# the mean family takes hier_aggregate's segment_sum fast path; "uniform"
# members ignore the weights argument (Algorithm 1 as written)
_fedavg_uniform.mean_family = "uniform"
_fedavg_weighted.mean_family = "weighted"

aggregators.register("median")(federated.coordinate_median)
aggregators.register("trimmed_mean")(federated.trimmed_mean)
# staleness-aware weighted FedAvg (w ∝ D_k/(1+staleness)^β): the async
# execution schedules pre-fold the per-arrival discount into the weights
# (federated.staleness_discount), so the registered entry takes the uniform
# (stacked, weights, mask) signature like every other aggregator
aggregators.register("staleness")(federated.staleness_weighted)


def get_aggregator(name: str):
    return aggregators.get(name)
