"""Back-compat re-export: the generic Registry moved to ``repro.registry``.

The scenario axis lives in ``repro.sim`` (which ``repro.api`` imports), so
the registry mechanism itself must sit below both packages to stay
import-cycle-free.  Existing ``repro.api.registry`` imports keep working.
"""

from repro.registry import Registry

__all__ = ["Registry"]
