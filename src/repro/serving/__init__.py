from repro.serving.decode import DecodeState, decode_tokens, make_decode_fn, make_prefill_fn

__all__ = ["DecodeState", "decode_tokens", "make_decode_fn", "make_prefill_fn"]
