"""Batched serving: prefill + single-token decode loop with KV/state caches.

``serve_step`` (one new token against a seq_len-deep cache) is what the
``decode_*``/``long_*`` dry-run shapes lower.  The decode sharding rules are
weight-stationary 2-D TP (see parallel/sharding.py); local-attention layers
use ring-buffer caches so a 32k context costs only ``window`` slots on
gemma2/recurrentgemma."""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as T


class DecodeState(NamedTuple):
    cache: Any
    pos: jax.Array  # current absolute position (int32 scalar)
    tokens: jax.Array  # last emitted token (B, 1)
    enc_out: Optional[jax.Array] = None  # encdec cross-attention memory


def make_prefill_fn(cfg: ModelConfig):
    def prefill_fn(params, batch, cache):
        logits, new_cache = T.prefill(params, batch, cfg, cache)
        last = jnp.argmax(logits[:, -1:, :], axis=-1)
        S = batch["tokens"].shape[1]
        return DecodeState(new_cache, jnp.asarray(S, jnp.int32), last)

    return prefill_fn


def make_decode_fn(cfg: ModelConfig, sample: str = "greedy", temperature: float = 1.0):
    def decode_fn(params, state: DecodeState, key=None):
        logits, new_cache = T.decode_step(params, state.tokens, state.cache,
                                          state.pos, cfg, enc_out=state.enc_out)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        else:
            nxt = jax.random.categorical(key, logits[:, -1, :] / temperature)[:, None]
        return DecodeState(new_cache, state.pos + 1, nxt, state.enc_out), logits

    return decode_fn


def decode_tokens(params, cfg: ModelConfig, prompt: jax.Array, max_new: int,
                  max_seq: Optional[int] = None, sample: str = "greedy", seed: int = 0):
    """Convenience driver: prefill prompt then generate ``max_new`` tokens."""
    B, S = prompt.shape
    max_seq = max_seq or (S + max_new)
    cache = T.init_cache(cfg, B, max_seq)
    batch = {"tokens": prompt, "labels": prompt}
    prefill_fn = make_prefill_fn(cfg)
    decode_fn = jax.jit(make_decode_fn(cfg, sample=sample))
    state = jax.jit(prefill_fn)(params, batch, cache)
    out = [state.tokens]
    key = jax.random.PRNGKey(seed)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        state, _ = decode_fn(params, state, sub)
        out.append(state.tokens)
    return jnp.concatenate(out, axis=1)
