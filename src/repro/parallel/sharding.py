"""Logical-axis sharding rules.

Model code annotates every parameter and activation with *logical* axis names
("batch", "embed", "heads", ...).  A rule-set maps logical names to mesh axis
names per shape-kind (train / prefill / decode), with automatic divisibility
fallback (a mesh axis that does not divide the dim is dropped — e.g.
starcoder2's 36 heads can't shard 16-way, so head sharding is dropped and
FSDP carries the memory).

Rule-set rationale (TPU v5e, mesh (data=16, model=16), optional pod=2):

* ``train``   — FSDP ("embed" over data) + TP ("heads"/"mlp"/"experts"/"vocab"
                over model).  Weights and optimizer state are fully sharded;
                XLA all-gathers each scanned layer's weights just-in-time and
                overlaps the gather with the previous layer's compute.
* ``prefill`` — long sequences: activations sequence-sharded over model
                (32k/16 = 2k per chip) + batch over data; weights stay
                FSDP+TP like train (prefill is compute-bound, gathers amortise).
* ``decode``  — weight-stationary: dense weights drop the data-axis (FSDP)
                sharding and live TP-resident (they fit; per-token FSDP
                gathers dominated decode ICI — §Perf iter 7).  MoE expert
                weights keep 2-D (experts×expert_embed) sharding: a 235B MoE
                cannot fit TP-only, so its per-layer gather is the measured
                price of unquantised serving.  KV cache: batch over data,
                cache length over model (flash-decode psums).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule sets: logical axis -> tuple of mesh axes (tried in order, longest
# divisible prefix wins).  "pod" entries are dropped automatically when the
# mesh has no pod axis.
# ---------------------------------------------------------------------------

Rules = dict[str, tuple[str, ...]]

RULESETS: dict[str, Rules] = {
    "train": {
        "batch": ("pod", "data"),
        "seq": (),
        "kv_seq": (),
        "embed": ("data",),          # FSDP axis
        "embed_pod": ("pod", "data"),  # FSDP over pod too (multi-pod weights)
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "expert_embed": ("data",),   # MoE weight D dim stays 2-D sharded
        "layers": (),
        "rank": (),                  # LoRA rank — tiny, never shard
        "state": (),                 # SSM state dim
        "conv": (),
    },
    "prefill": {
        "batch": ("pod", "data"),
        "seq": ("model",),           # sequence parallelism for 32k prefill
        "kv_seq": ("model",),
        "embed": ("data",),
        "embed_pod": ("pod", "data"),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "expert_embed": ("data",),
        "layers": (),
        "rank": (),
        "state": (),
        "conv": (),
    },
    "decode": {
        "batch": ("pod", "data"),
        "seq": (),
        "kv_seq": ("model",),        # flash-decode: cache length sharded
        "embed": (),                 # weight-stationary: dense weights fit via
                                     # TP; FSDP gathers per token dominated
                                     # decode ICI (§Perf iter 7)
        "embed_pod": (),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "expert_embed": ("data",),   # 235B-class MoE can't fit TP-only
        "layers": (),
        "rank": (),
        "state": (),
        "conv": (),
    },
}


def activation_rules(kind: str) -> Rules:
    return RULESETS[kind]


# ---------------------------------------------------------------------------
# Active sharding context (mesh + rules).  Model code calls shard(x, axes);
# outside a context it is the identity, so pure-CPU tests need no mesh.
# ---------------------------------------------------------------------------


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None


_CTX = _Ctx()


def set_context(mesh: Optional[Mesh], rules: Optional[Rules]) -> None:
    _CTX.mesh = mesh
    _CTX.rules = rules


def active_context() -> tuple[Optional[Mesh], Optional[Rules]]:
    return _CTX.mesh, _CTX.rules


@contextlib.contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[Rules]):
    prev = active_context()
    set_context(mesh, rules)
    try:
        yield
    finally:
        set_context(*prev)


# ---------------------------------------------------------------------------
# Spec computation with divisibility fallback
# ---------------------------------------------------------------------------


def _mesh_axis_sizes(mesh) -> dict[str, int]:
    # works for both Mesh and AbstractMesh
    return dict(mesh.shape)


def spec_for(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """PartitionSpec for an array of ``shape`` with logical ``axes``.

    For each dim, map the logical axis through ``rules`` to a tuple of mesh
    axes; keep the longest prefix whose product divides the dim size; never
    reuse a mesh axis across dims (GSPMD requirement).
    """
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    out: list[Any] = []
    for dim, ax in zip(shape, axes):
        if ax is None or ax not in rules:
            out.append(None)
            continue
        mesh_axes = [a for a in rules[ax] if a in sizes and a not in used]
        chosen: list[str] = []
        prod = 1
        for a in mesh_axes:
            if dim % (prod * sizes[a]) == 0:
                chosen.append(a)
                prod *= sizes[a]
            else:
                break
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
            used.add(chosen[0])
        else:
            out.append(tuple(chosen))
            used.update(chosen)
    # strip trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate activation ``x`` with logical axes (no-op outside a context)."""
    mesh, rules = active_context()
    if mesh is None or rules is None:
        return x
    spec = spec_for(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter declaration: builders make ParamLeaf(value, axes); split_param_tree
# separates values from logical-axes metadata with identical tree structure.
# ---------------------------------------------------------------------------


class ParamLeaf(NamedTuple):
    value: Any  # jax.Array | jax.ShapeDtypeStruct
    axes: tuple[Optional[str], ...]


def make_param(
    key: Optional[jax.Array],
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    dtype: Any,
    init: str = "normal",
    scale: float = 0.02,
    abstract: bool = False,
) -> ParamLeaf:
    """Create one parameter (or its ShapeDtypeStruct when ``abstract``)."""
    shape = tuple(int(s) for s in shape)
    assert len(shape) == len(axes), (shape, axes)
    if abstract:
        return ParamLeaf(jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)), tuple(axes))
    if init == "normal":
        v = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    elif init == "zeros":
        v = jnp.zeros(shape, dtype=jnp.float32)
    elif init == "ones":
        v = jnp.ones(shape, dtype=jnp.float32)
    elif init == "uniform":
        v = jax.random.uniform(key, shape, dtype=jnp.float32, minval=-scale, maxval=scale)
    else:
        raise ValueError(init)
    return ParamLeaf(v.astype(dtype), tuple(axes))


def _is_leaf(x) -> bool:
    return isinstance(x, ParamLeaf)


def split_param_tree(tree):
    """tree of ParamLeaf -> (values_tree, axes_tree) with identical structure."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_leaf)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_leaf)
    return values, axes


def named_sharding_tree(axes_tree, values_tree, mesh: Mesh, rules: Rules):
    """Build a NamedSharding tree for params given their logical axes."""

    def one(axes, val):
        return NamedSharding(mesh, spec_for(val.shape, axes, rules, mesh))

    return jax.tree.map(one, axes_tree, values_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))
