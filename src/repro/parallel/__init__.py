from repro.parallel.sharding import (
    ParamLeaf,
    RULESETS,
    activation_rules,
    active_context,
    make_param,
    named_sharding_tree,
    set_context,
    shard,
    sharding_context,
    spec_for,
    split_param_tree,
)

__all__ = [
    "ParamLeaf",
    "RULESETS",
    "activation_rules",
    "active_context",
    "make_param",
    "named_sharding_tree",
    "set_context",
    "shard",
    "sharding_context",
    "spec_for",
    "split_param_tree",
]
