"""Split-learning microbatch pipelining (beyond paper).

Algorithm 2 is strictly sequential per local iteration:
    client fwd  →  uplink A_k  →  server fwd/bwd  →  downlink dA_k  →
    client bwd
so the client idles during server compute + transfers and vice versa.
Splitting the local batch into M microbatches pipelines the stages
(GPipe-style, applied across the *wireless* split): while the server
processes microbatch j, the client already runs forward on j+1.

Two deliverables here:

  * ``pipelined_split_grads`` — numerically exact microbatched split
    value+grad (mean over microbatches == full-batch, verified in tests).
    On the TPU mesh the client/server stages are the two halves of the
    scanned stack, so XLA's scheduler overlaps the per-microbatch halves.
  * ``pipeline_round_time`` — the latency model: sequential cost
    M·(t_cl + t_up + t_srv + t_down + t_cl_bwd) collapses to
    max-stage-bound  (sum of stages) + (M−1)·max(stage)  — the paper's
    delay model extended with the overlap factor, used to quantify the
    benefit under the §IV channel draws.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import split as split_lib


def _slice_batch(batch, lo, size):
    return jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, lo, size, axis=0),
                        batch)


def pipelined_split_grads(params, lora_c, lora_s, batch, cfg: ModelConfig,
                          cut: int, num_microbatches: int):
    """Microbatched split step: mean loss/grads over M microbatches.

    Exactly equals the full-batch split step when B % M == 0 (tested); the
    microbatch loop is a ``lax.scan`` so the client/server halves of
    consecutive microbatches are independent nodes XLA can overlap."""
    B = jax.tree.leaves(batch)[0].shape[0]
    M = num_microbatches
    assert B % M == 0, (B, M)
    mb = B // M

    def body(carry, i):
        loss_acc, dc_acc, ds_acc = carry
        sub = _slice_batch(batch, i * mb, mb)
        loss, dc, ds, _ = split_lib.split_value_and_grad(params, lora_c, lora_s,
                                                         sub, cfg, cut)
        loss_acc = loss_acc + loss
        dc_acc = jax.tree.map(jnp.add, dc_acc, dc)
        ds_acc = jax.tree.map(jnp.add, ds_acc, ds)
        return (loss_acc, dc_acc, ds_acc), None

    zeros_c = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), lora_c)
    zeros_s = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), lora_s)
    (loss, dc, ds), _ = jax.lax.scan(body, (jnp.zeros(()), zeros_c, zeros_s),
                                     jnp.arange(M))
    inv = 1.0 / M
    scale = lambda t: jax.tree.map(lambda x: x * inv, t)
    return loss * inv, scale(dc), scale(ds)


def pipeline_round_time(stage_seconds: dict[str, np.ndarray | float],
                        num_microbatches: int) -> dict[str, Any]:
    """Latency of one local iteration with M microbatches.

    stage_seconds: {client_fwd, uplink, server, downlink, client_bwd} —
    full-batch stage times (scalars or per-client arrays).  Each microbatch
    costs stage/M; the pipeline completes in  sum(stages)/M + (M−1)/M ·
    max(stage)  vs the sequential  sum(stages)."""
    stages = {k: np.asarray(v, dtype=float) for k, v in stage_seconds.items()}
    total = sum(stages.values())
    if num_microbatches <= 1:
        return {"sequential_s": total, "pipelined_s": total, "speedup": np.ones_like(total)}
    M = num_microbatches
    bottleneck = np.maximum.reduce([v for v in stages.values()])
    pipelined = total / M + (M - 1) / M * bottleneck
    return {
        "sequential_s": total,
        "pipelined_s": pipelined,
        "speedup": total / pipelined,
        "bottleneck_s": bottleneck,
    }


def split_stage_times(cfg_feds, net, eta: float, A: float, alloc,
                      model_params=None,
                      downlink_frac: float = 0.1) -> dict[str, np.ndarray]:
    """Derive per-stage times from the paper's delay model + an allocation:
    client/server compute from eq. (10) split by A, uplink from t_s, and a
    ``downlink_frac``-scaled downlink estimate (the paper treats the
    downlink as negligible; the default 0.1 keeps the standalone pipeline
    model conservative, while the ``pipelined`` execution schedule passes 0
    so its stage sum matches eq. (15)'s round total exactly)."""
    from repro.core import delay_model as dm

    tau = dm.compute_time(cfg_feds, net, eta, A, model_params)
    V = dm.local_iters(cfg_feds, eta)
    w = float(model_params if model_params is not None else cfg_feds.sample_dim)
    E_k = dm.lemma_v(cfg_feds) * w * net.C_k * net.D_k
    t_cl = E_k * np.log2(1.0 / eta) * (A / net.f_max) / V
    t_srv = E_k * np.log2(1.0 / eta) * ((1.0 - A) / net.f_server) / V
    return {
        "client_fwd": 0.5 * t_cl,
        "uplink": np.asarray(alloc.t_s, float),
        "server": t_srv,
        "downlink": downlink_frac * np.asarray(alloc.t_s, float),  # high-power BS
        "client_bwd": 0.5 * t_cl,
    }
