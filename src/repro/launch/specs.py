"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  Modality frontends are stubs per the assignment: the VLM
cell gets precomputed CLIP-L patch embeddings (B, Tv, 1024); the audio cell
gets precomputed log-mel frame embeddings (B, 1500, d_model)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    Tv = 0
    if cfg.family == "vlm":
        Tv = min(cfg.vision_tokens, S // 2)
        batch["vision_embeds"] = sds((B, Tv, 1024), cfg.dtype)
    if cfg.family == "encdec":
        batch["frame_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    batch["tokens"] = sds((B, S - Tv), jnp.int32)
    batch["labels"] = sds((B, S), jnp.int32)
    batch["mask"] = sds((B, S), jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b = train_batch_specs(cfg, shape)
    return b


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return sds((shape.global_batch, 1), jnp.int32)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract KV/state cache tree via eval_shape (no allocation)."""
    from repro.models import transformer as T

    B, S = shape.global_batch, shape.seq_len

    def mk():
        return T.init_cache(cfg, B, S)

    return jax.eval_shape(mk)


def enc_out_specs(cfg: ModelConfig, shape: ShapeConfig):
    if cfg.family != "encdec":
        return None
    return sds((shape.global_batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)


def input_specs(arch: str, shape_name: str = "train_4k") -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Training cells: {tokens, labels, mask} (+ modality-stub embeddings);
    prefill: the request batch; decode: {tokens (B,1), cache, pos}."""
    from repro.config import SHAPES, get_arch

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape)
    out = {"tokens": decode_token_specs(cfg, shape),
           "cache": cache_specs(cfg, shape),
           "pos": sds((), jnp.int32)}
    if cfg.family == "encdec":
        out["enc_out"] = enc_out_specs(cfg, shape)
    return out


def concrete_like(specs, key=None, scale: float = 1.0):
    """Materialise a spec tree with deterministic values (smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(k, leaf.shape, 0, 100).astype(leaf.dtype))
        elif jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append((jax.random.normal(k, leaf.shape) * scale).astype(leaf.dtype))
        else:
            out.append(jnp.zeros(leaf.shape, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
