"""Serving driver: batched prefill + decode with KV/state caches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_arch, smoke_variant
from repro.models import transformer as T
from repro.serving.decode import decode_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedsllm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params, _ = T.init_params(cfg, key=jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    out = decode_tokens(params, cfg, prompt, args.max_new)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print("sample tokens:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
