"""Step builders: jittable train / prefill / serve steps + their sharding
trees for a given (arch, shape, mesh) cell.

The same builders serve the real trainer (concrete arrays) and the dry-run
(ShapeDtypeStructs): everything here is shape-polymorphic and pure.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.optim.grad_utils import clip_by_global_norm
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import cosine_with_warmup
from repro.parallel import RULESETS, spec_for
from repro.parallel.sharding import Rules


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def _axes_is_leaf(t):
    return isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t)


def param_shardings(axes_tree, values_tree, mesh: Mesh, rules: Rules):
    def one(axes, val):
        return NamedSharding(mesh, spec_for(val.shape, axes, rules, mesh))

    return jax.tree.map(one, axes_tree, values_tree, is_leaf=_axes_is_leaf)


def opt_state_shardings(param_sh, opt_state_abstract):
    """Optimizer moments mirror parameter shardings (ZeRO-via-FSDP)."""

    def like(sub):
        return param_sh

    out = {}
    for k, v in opt_state_abstract.items():
        out[k] = param_sh  # m/v trees have identical structure to params
    return out


def batch_shardings(batch_specs, mesh: Mesh, rules: Rules, kind: str):
    def one(path_leaf, leaf):
        name = path_leaf
        shape = leaf.shape
        if name in ("tokens", "labels", "mask"):
            axes = ("batch", "seq")
        elif name == "vision_embeds":
            axes = ("batch", "seq", None)
        elif name == "frame_embeds":
            axes = ("batch", None, "embed")
        else:
            axes = tuple([None] * len(shape))
        return NamedSharding(mesh, spec_for(shape, axes, rules, mesh))

    return {k: one(k, v) for k, v in batch_specs.items()}


def cache_shardings(cache_tree, mesh: Mesh, rules: Rules):
    axes = T.cache_axes(cache_tree)
    return jax.tree.map(
        lambda a, v: NamedSharding(mesh, spec_for(v.shape, a, rules, mesh)),
        axes, cache_tree, is_leaf=_axes_is_leaf)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, *, unroll: bool = False):
    lr = cosine_with_warmup(tcfg.learning_rate, tcfg.warmup_steps, tcfg.total_steps)
    opt = get_optimizer(tcfg.optimizer, lr, tcfg)
    remat = tcfg.remat != "none"
    loss_fn = functools.partial(T.loss_fn, cfg=cfg, remat=remat, unroll=unroll)

    def grads_of(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            # gradient accumulation: scan over microbatches (fp32 accumulators)
            M = tcfg.microbatch
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % M == 0, (B, M)
            mb = B // M

            def body(carry, i):
                loss_a, g_a = carry
                sub = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0),
                    batch)
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, sub)
                g_a = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_a, g)
                return (loss_a + loss, g_a), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, g), metrics = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                              jnp.arange(M))
            inv = 1.0 / M
            return (loss * inv, jax.tree.map(lambda m: m[-1], metrics)), \
                jax.tree.map(lambda x: x * inv, g)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return (loss, metrics), grads

    def train_step(params, opt_state, step, batch):
        (loss, metrics), grads = grads_of(params, batch)
        grads, gn = clip_by_global_norm(grads, tcfg.grad_clip)
        params, opt_state = opt.update(grads, opt_state, params, step)
        out_metrics = {"loss": loss, "grad_norm": gn, **metrics}
        return params, opt_state, step + 1, out_metrics

    return train_step, opt


def abstract_opt_state(opt, params_abstract):
    return jax.eval_shape(opt.init, params_abstract)


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, *, unroll: bool = False):
    def prefill_step(params, batch, cache):
        return T.prefill(params, batch, cfg, cache, unroll=unroll)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, unroll: bool = False):
    def serve_step(params, tokens, cache, pos, enc_out=None):
        logits, new_cache = T.decode_step(params, tokens, cache, pos, cfg,
                                          enc_out=enc_out, unroll=unroll)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return nxt, new_cache

    return serve_step
