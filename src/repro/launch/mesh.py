"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first jax
init, and smoke tests must keep seeing 1 device.

``axis_types`` landed in jax.sharding after 0.4.37; every constructor here
feature-detects it so the same code runs on both the pinned container
toolchain and newer jax.
"""

from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # jax <= 0.4.37: implicit auto axes
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips ('data','model') per pod; 2 pods with a leading
    'pod' axis for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), **_axis_types_kw(1))


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-less mesh for spec math, across the AbstractMesh API change:
    jax >= 0.5 takes ``(shape, axis_names)``; 0.4.x takes name/size pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
