"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS *before* first jax
init, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips ('data','model') per pod; 2 pods with a leading
    'pod' axis for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (CPU tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
