"""Training driver.

Runs a real training loop on whatever devices exist (CPU-sized configs in
this container; the same code path drives the production mesh — the sharding
context comes from ``--mesh``).  Features: checkpoint/auto-resume (atomic,
elastic), deterministic index-based data, cosine schedule, grad clipping,
periodic eval, straggler-tolerant FedsLLM mode (``--fedsllm``).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch fedsllm-100m \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch fedsllm-100m --fedsllm \
      --clients 8 --rounds 5 --eta 0.5 --cohort 4 --deadline 120
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.config import FedsLLMConfig, TrainConfig, get_arch, smoke_variant
from repro.data.tokens import TokenStream
from repro.launch.steps import make_train_step
from repro.models import transformer as T


def train_standard(args):
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20),
                       remat="full" if args.remat else "none")
    params, axes = T.init_params(cfg, key=jax.random.PRNGKey(tcfg.seed))
    step_fn, opt = make_train_step(cfg, tcfg)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt is not None:
        got = ckpt.restore_or_none()
        if got is not None:
            (params, opt_state, step), meta = got
            start = int(meta["step"])
            print(f"resumed from step {start}")

    stream = TokenStream(args.batch, args.seq, cfg.vocab_size, seed=tcfg.seed)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = stream.batch_at(i)
        params, opt_state, step, metrics = jit_step(params, opt_state, step, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {i:5d}  loss {loss:.4f}  gnorm {float(metrics['grad_norm']):.3f}"
                  f"  ({time.time()-t0:.1f}s)", flush=True)
        if ckpt is not None and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, (params, opt_state, step))
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt_state, step))
    return params


def train_fedsllm(args):
    """Paper mode: a multi-round FedsLLM campaign with simulated wireless.

    One ``Experiment`` wires model init, the split cut, the jitted round
    function, the §IV channel model and the delay-minimisation allocator;
    the strategy axes are selected by name (--aggregator/--allocator/--codec).
    ``Experiment.run`` (the ``repro.sim`` campaign engine) then drives the
    rounds: per-round channel evolution under the named --scenario (disable
    with --freeze-channel; re-solve the allocator jointly per round — η
    included — with --reallocate), elastic cohorts (--cohort < --clients),
    deadline stragglers (--deadline) and periodic checkpointing with
    auto-resume (--ckpt-dir/--ckpt-every).
    """
    from repro.api import Experiment
    from repro.config import RunConfig, ShapeConfig

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    run_cfg = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", "train", args.seq, args.batch),
        fedsllm=FedsLLMConfig(num_clients=args.clients),
    )
    exp = Experiment.from_config(run_cfg, eta=args.eta, lora_rank=args.lora_rank,
                                 aggregator=args.aggregator,
                                 allocator=args.allocator, compressor=args.codec,
                                 scenario=args.scenario,
                                 topology=args.topology,
                                 schedule=args.schedule,
                                 local_algo=args.local_algo,
                                 workload=args.workload)
    print(exp.describe())

    stream = TokenStream(args.batch, args.seq, cfg.vocab_size, seed=0)
    t0 = time.time()

    def log(rec):
        print(f"round {rec.round:3d}  "
              f"survivors {rec.survivors}/{rec.cohort_size}  "
              f"loss_start {rec.metrics['loss_round_start']:.4f}  "
              f"loss_local_end {rec.metrics['loss_local_final']:.4f}  "
              f"simulated {rec.cumulative_time:9.1f}s  "
              f"({time.time()-t0:.1f}s)", flush=True)

    res = exp.run(num_rounds=args.rounds, stream=stream,
                  cohort=args.cohort or None,
                  resample_channel=not args.freeze_channel,
                  reallocate=args.reallocate, deadline=args.deadline,
                  stop_at_lemma1=args.stop_lemma1,
                  checkpoint_dir=args.ckpt_dir,
                  checkpoint_every=args.ckpt_every if args.ckpt_dir else 0,
                  resume=bool(args.ckpt_dir), on_round=log)
    print(f"{res.num_rounds} rounds ({res.stopped_by}; Lemma-1 budget "
          f"{res.rounds_lemma1}), {res.total_time:.1f}s simulated, "
          f"straggler rate {res.straggler_rate:.1%}, jit traces {exp.trace_count}")
    return res.state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedsllm-100m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    # fedsllm mode
    ap.add_argument("--fedsllm", action="store_true")
    ap.add_argument("--clients", type=int, default=8,
                    help="simulated radio population K")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--cohort", type=int, default=0,
                    help="clients trained per round (< clients = elastic "
                         "subsampling; 0 = all)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-round straggler deadline, simulated seconds")
    ap.add_argument("--freeze-channel", action="store_true",
                    help="keep round 0's channel draw for every round")
    ap.add_argument("--reallocate", action="store_true",
                    help="re-solve the allocator on every round's channel draw")
    ap.add_argument("--stop-lemma1", action="store_true",
                    help="cap rounds at Lemma 1's a/(1-eta) budget")
    ap.add_argument("--eta", type=float, default=0.5)
    ap.add_argument("--lora-rank", type=int, default=8)
    ap.add_argument("--aggregator", default="weighted",
                    help="fed-server reduction (repro.api.aggregators)")
    ap.add_argument("--allocator", default="proposed",
                    help="resource-allocation strategy (repro.api.allocators)")
    ap.add_argument("--codec", default="none",
                    help="smashed-activation uplink codec (repro.api.compressors)")
    ap.add_argument("--scenario", default="blockfade",
                    help="channel-dynamics scenario (repro.sim.scenario): "
                         "frozen | blockfade | geo-blockfade | drift | "
                         "hetero | outage | shadowing")
    ap.add_argument("--topology", default="star",
                    help="network graph (repro.net.topology): star | "
                         "edge-cloud | edge-agg | relay; non-star needs a "
                         "geometry scenario, e.g. --scenario geo-blockfade")
    ap.add_argument("--schedule", default="sync",
                    help="execution discipline (repro.des.schedules): sync "
                         "| pipelined | async | semi-async; async runs the "
                         "full population and aggregates arrivals "
                         "staleness-weighted")
    ap.add_argument("--local-algo", default="gd",
                    help="client local-update rule (repro.fl.local_algos): "
                         "gd | fedprox | scaffold")
    ap.add_argument("--workload", default="iid",
                    help="per-client data distribution (repro.fl.workloads): "
                         "iid | quantity-skew | length-skew | dirichlet")
    args = ap.parse_args()
    if args.fedsllm:
        train_fedsllm(args)
    else:
        train_standard(args)


if __name__ == "__main__":
    main()
