import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we produce:
  * the FULL scanned-model step compiled on the production mesh —
    memory_analysis() proves it fits, the collective schedule is recorded,
    and compilation success proves the sharding config is coherent;
  * composition lowerings for the roofline: HLO cost analysis counts a
    ``lax.scan`` body ONCE (verified in this container), so per-cell we also
    lower loop-free reduced-depth variants: M1 (one layer group, unrolled),
    M2 (two groups, unrolled), and M1t (one group + remainder tail) —
    per-group cost = M2 − M1, stem cost = M1 − per-group, tail = M1t − M1,
    total = stem + n_groups·per-group + tail.  This is exact up to XLA
    fusion differences (reported as MODEL_FLOPS ratio in §Roofline).

Results go to results/dryrun/<arch>__<shape>__<mesh>.json (incremental:
existing cells are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch starcoder2-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (SHAPES, TrainConfig, get_arch, list_archs,
                          shape_applicable)
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.registry import active_param_count, count_params
from repro.parallel import RULESETS, sharding_context

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64|s16|u16)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8, "s16": 2, "u16": 2}


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OP_LINE_RE = re.compile(r"=\s*((?:\([^)]*\)|\S+))\s+[\w-]+\(")


def parse_s2_traffic(hlo_text: str, s_threshold: int = 256) -> float:
    """Bytes of attention-logit/prob intermediates — the (…, S_q, S_kv)
    tensors a flash kernel keeps in VMEM instead of HBM.

    Matched structurally: rank ≥ 5 with BOTH trailing dims ≥ threshold
    (attention scores here are rank-5 `bkrqs` / rank-6 banded `bnkrqs`;
    activations are rank-3, weights rank-2/3, MoE buffers rank-4, and decode
    scores have a trailing (1, S) pair — none match).  Used for the
    `memory_s_flash` roofline column: the Pallas flash-attention kernel
    (oracle-validated) never round-trips these through HBM; the jnp fallback
    the dry-run lowers does."""
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        shape_txt = m.group(1)
        for dt, dims in _SHAPE_RE.findall(shape_txt):
            if not dims:
                continue
            ds = [int(d) for d in dims.split(",")]
            if len(ds) >= 5 and ds[-1] >= s_threshold and ds[-2] >= s_threshold:
                n = 1
                for d in ds:
                    n *= d
                total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    """Per-device ICI bytes using ring-model factors.

    all-gather: (G-1)/G·out; all-reduce: 2(G-1)/G·size; reduce-scatter:
    (G-1)·out; all-to-all: (G-1)/G·size; collective-permute: size."""
    per_op: dict[str, float] = {}
    count: dict[str, int] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, op, _ = m.groups()
        size = _shape_bytes(shape_txt)
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            ge = _GROUPS_EXPL_RE.search(line)
            if ge:
                g = len(ge.group(1).split(","))
        if not g or g <= 1:
            continue
        if op == "all-gather":
            b = (g - 1) / g * size
        elif op == "all-reduce":
            b = 2 * (g - 1) / g * size
        elif op == "reduce-scatter":
            b = (g - 1) * size
        elif op == "all-to-all":
            b = (g - 1) / g * size
        else:  # collective-permute
            b = float(size)
        per_op[op] = per_op.get(op, 0.0) + b
        count[op] = count.get(op, 0) + 1
        total += b
    return {"bytes_per_device": total, "per_op_bytes": per_op, "op_counts": count}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def _lower_cell(cfg, shape, mesh, rules, *, unroll: bool, tcfg: TrainConfig):
    """Lower + compile one (cfg, shape) on mesh. Returns analysis dict."""
    with sharding_context(mesh, rules):
        params, axes = T.init_params(cfg, abstract=True)
        psh = ST.param_shardings(axes, params, mesh, rules)
        kind = shape.kind

        if kind == "train":
            step_fn, opt = ST.make_train_step(cfg, tcfg, unroll=unroll)
            opt_state = ST.abstract_opt_state(opt, params)
            osh = jax.tree.map(lambda _: 0, opt_state, is_leaf=lambda x: x is None)
            osh = {k: psh for k in opt_state}  # m/v mirror params
            batch = SP.train_batch_specs(cfg, shape)
            bsh = ST.batch_shardings(batch, mesh, rules, kind)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            ssh = NamedSharding(mesh, P())
            jfn = jax.jit(step_fn,
                          in_shardings=(psh, osh, ssh, bsh),
                          out_shardings=(psh, osh, ssh, None),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(params, opt_state, step_spec, batch)
        elif kind == "prefill":
            pf = ST.make_prefill_step(cfg, unroll=unroll)
            batch = SP.prefill_batch_specs(cfg, shape)
            bsh = ST.batch_shardings(batch, mesh, rules, kind)
            cache = SP.cache_specs(cfg, shape)
            csh = ST.cache_shardings(cache, mesh, rules)
            jfn = jax.jit(pf, in_shardings=(psh, bsh, csh),
                          out_shardings=(None, csh), donate_argnums=(2,))
            lowered = jfn.lower(params, batch, cache)
        else:  # decode
            sv = ST.make_serve_step(cfg, unroll=unroll)
            toks = SP.decode_token_specs(cfg, shape)
            tsh = NamedSharding(mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names), None)) \
                if shape.global_batch % np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names]) == 0 \
                else NamedSharding(mesh, P())
            cache = SP.cache_specs(cfg, shape)
            csh = ST.cache_shardings(cache, mesh, rules)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            args = [params, toks, cache, pos]
            in_sh = [psh, tsh, csh, NamedSharding(mesh, P())]
            if cfg.family == "encdec":
                eo = SP.enc_out_specs(cfg, shape)
                esh = ST.batch_shardings({"frame_embeds": eo}, mesh, rules, kind)["frame_embeds"]
                args.append(eo)
                in_sh.append(esh)
            jfn = jax.jit(sv, in_shardings=tuple(in_sh),
                          out_shardings=(None, csh), donate_argnums=(2,))
            lowered = jfn.lower(*args)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        print(f"    memory_analysis: {ma}", flush=True)
        print(f"    cost_analysis: flops={ca.get('flops', 0.0):.4g} "
              f"bytes={ca.get('bytes accessed', 0.0):.4g} (per device)", flush=True)
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        return {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            "s2_bytes_per_device": parse_s2_traffic(hlo),
            "collectives": coll,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "total_hbm_bytes": ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
            },
            "compile_seconds": compile_s,
            "hlo_bytes": len(hlo),
        }


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, calibrate: bool = True,
             out_dir: str = RESULTS_DIR, force: bool = False) -> Optional[dict]:
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(arch, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "skipped": True,
               "reason": "full-attention arch at 500k context (DESIGN.md §5)"}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = RULESETS[shape.kind]
    tcfg = TrainConfig()
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "num_devices": int(np.prod(list(mesh.shape.values()))),
        "params_total": count_params(cfg),
        "params_active": active_param_count(cfg),
        "skipped": False,
    }
    t_all = time.time()
    try:
        rec["full"] = _lower_cell(cfg, shape, mesh, rules, unroll=False, tcfg=tcfg)
        if calibrate and mesh_kind == "single":
            gs = cfg.group_size
            rem = cfg.num_layers % gs
            m1 = _lower_cell(cfg.replace(num_layers=gs), shape, mesh, rules,
                             unroll=True, tcfg=tcfg)
            m2 = _lower_cell(cfg.replace(num_layers=2 * gs), shape, mesh, rules,
                             unroll=True, tcfg=tcfg)
            rec["m1"], rec["m2"] = m1, m2
            if rem:
                rec["m1t"] = _lower_cell(cfg.replace(num_layers=gs + rem), shape,
                                         mesh, rules, unroll=True, tcfg=tcfg)
            rec["composed"] = compose_costs(rec, cfg)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_seconds"] = time.time() - t_all
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def compose_costs(rec: dict, cfg) -> dict:
    """total = stem + n_groups·per_group + tail (see module docstring)."""
    n_groups = cfg.num_groups

    def get(d, *ks):
        for k in ks:
            d = d[k]
        return d

    out = {}
    for key, path in [("flops_per_device", ("flops_per_device",)),
                      ("bytes_per_device", ("bytes_per_device",)),
                      ("s2_bytes_per_device", ("s2_bytes_per_device",)),
                      ("collective_bytes_per_device", ("collectives", "bytes_per_device"))]:
        c1 = get(rec["m1"], *path)
        c2 = get(rec["m2"], *path)
        per_group = max(c2 - c1, 0.0)
        stem = max(c1 - per_group, 0.0)
        tail = max(get(rec["m1t"], *path) - c1, 0.0) if "m1t" in rec else 0.0
        out[key] = stem + n_groups * per_group + tail
        out[key + "_per_group"] = per_group
        out[key + "_stem"] = stem
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def all_cells():
    for arch in list_archs():
        if arch == "fedsllm-100m":
            continue  # example model, not an assigned cell
        for shape_name in SHAPES:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", type=str, default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    for arch, shape_name in cells:
        for mk in meshes:
            t0 = time.time()
            rec = run_cell(arch, shape_name, mk, out_dir=args.out, force=args.force)
            status = "SKIP" if rec.get("skipped") else ("OK" if rec.get("ok") else "FAIL")
            print(f"[{status}] {arch} × {shape_name} × {mk}  ({time.time()-t0:.1f}s)",
                  flush=True)
            if status == "FAIL":
                print(rec.get("error"), flush=True)


if __name__ == "__main__":
    main()
