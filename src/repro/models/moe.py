"""Mixture-of-Experts FFN: top-k routing with static capacity, rank-based
dispatch, expert-parallel over the ``model`` mesh axis.

Final dispatch design (perf iterations 1-4, EXPERIMENTS.md §Perf):

  * routing/top-k on (B,S,E) logits under GSPMD (small);
  * rank-within-expert via **argsort** — every intermediate is a (b, S·k)
    int array (the one-hot/cumsum formulation materialises (b, S·k, E):
    TBs at qwen3 scale);
  * dispatch + combine run inside **shard_map over the full (data, model)
    mesh**: each (data, model) shard scatters only the tokens routed to its
    LOCAL experts (token activations are replicated over ``model`` inside a
    data shard, so dispatch needs *zero* forward communication), the expert
    buffers emerge already (batch→data, expert→model)-sharded for the expert
    einsums, and the combine produces per-model-shard partial outputs that a
    single (b,S,D) ``psum`` over ``model`` reduces — the canonical
    expert-parallel pattern with one small collective per layer.

  History (measured on qwen3-235b train_4k, per-device roofline terms):
    v0 global flat scatter     : GSPMD replicates; 543s compute / 601s coll
    v1 batched scatter         : 5.9s compute but 137GB/layer all-reduces
    v3 shard_map(data) dispatch: 5.5s / 115s mem / 125s coll (E all-gathers)
    v4 this file               : see EXPERIMENTS.md §Perf

Overflow beyond an expert's per-row capacity C = ceil(cf·S·k/E) is dropped
(GShard/Switch semantics, cf = 1.25).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.parallel import make_param, shard
from repro.parallel.sharding import active_context, spec_for

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig, abstract=False):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4) if key is not None else [None] * 4
    return {
        "router": make_param(ks[0], (D, E), ("embed", None), "float32", abstract=abstract),
        "w_gate": make_param(ks[1], (E, D, F), ("experts", "expert_embed", "mlp"), cfg.param_dtype, abstract=abstract),
        "w_up": make_param(ks[2], (E, D, F), ("experts", "expert_embed", "mlp"), cfg.param_dtype, abstract=abstract),
        "w_down": make_param(ks[3], (E, F, D), ("experts", "mlp", "expert_embed"), cfg.param_dtype,
                             scale=0.02 / math.sqrt(2 * cfg.num_layers), abstract=abstract),
    }


def expert_capacity(seq_tokens: int, cfg: ModelConfig) -> int:
    """Per-batch-row expert capacity."""
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    c = int(math.ceil(CAPACITY_FACTOR * seq_tokens * k / E))
    c = max(c, min(seq_tokens * k, 8))
    return ((c + 7) // 8) * 8


# ---------------------------------------------------------------------------
# Local (per-shard) dispatch / combine
# ---------------------------------------------------------------------------


def _rank_and_dest(top_e, E: int, C: int, k: int):
    """Argsort-based rank within expert. top_e: (b, S, k) -> dest/keep (b, Sk)."""
    b, S, _ = top_e.shape
    Sk = S * k
    flat_e = top_e.reshape(b, Sk)
    order = jnp.argsort(flat_e, axis=1, stable=True)  # groups equal experts
    se = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.broadcast_to(jnp.arange(Sk)[None, :], (b, Sk))
    newseg = jnp.concatenate(
        [jnp.ones((b, 1), bool), se[:, 1:] != se[:, :-1]], axis=1)
    seg_start = jax.lax.cummax(jnp.where(newseg, idx, 0), axis=1)
    rank_sorted = idx - seg_start
    inv_order = jnp.argsort(order, axis=1)
    rank = jnp.take_along_axis(rank_sorted, inv_order, axis=1)  # (b, Sk)
    keep = rank < C
    dest = jnp.where(keep, flat_e * C + rank, E * C)  # E*C = global drop slot
    return dest, keep


def _dispatch_local(x, dest, keep, *, E_local: int, C: int, k: int, e_offset):
    """Scatter the local shard's tokens into its local expert buffers.

    x: (b, S, D); dest/keep: (b, S·k) with *global* slot ids.  Only slots
    belonging to experts [e_offset, e_offset + E_local) are kept."""
    b, S, D = x.shape
    Sk = S * k
    local_dest = dest - e_offset * C
    valid = keep & (local_dest >= 0) & (local_dest < E_local * C)
    local_dest = jnp.where(valid, local_dest, E_local * C)  # drop slot
    src_token = jnp.arange(Sk) // k
    xsrc = jnp.take_along_axis(
        x, jnp.broadcast_to(src_token[None, :, None], (b, Sk, 1)), axis=1)
    buf = jnp.zeros((b, E_local * C + 1, D), dtype=x.dtype)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, Sk))
    buf = buf.at[bidx, local_dest].set(xsrc, mode="drop")
    return buf[:, : E_local * C].reshape(b, E_local, C, D)


def _combine_local(ye, dest, keep, w_flat, *, S: int, k: int, e_offset):
    """Gather this shard's expert outputs back to its tokens (partial sum —
    tokens whose (token, slot) lives on another expert shard contribute 0
    here and are completed by the psum over ``model``)."""
    b, E_local, C, D = ye.shape
    local_dest = dest - e_offset * C
    valid = keep & (local_dest >= 0) & (local_dest < E_local * C)
    safe = jnp.where(valid, local_dest, E_local * C)
    yflat = jnp.concatenate([ye.reshape(b, E_local * C, D),
                             jnp.zeros((b, 1, D), ye.dtype)], axis=1)
    contrib = jnp.take_along_axis(yflat, safe[..., None], axis=1)  # (b,Sk,D)
    w = (w_flat * valid).astype(ye.dtype)
    return jnp.sum((contrib * w[..., None]).reshape(b, S, k, D), axis=2)


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = expert_capacity(S, cfg)

    # --- routing (fp32 logits; softmax over the selected k — qwen3/mixtral
    # norm_topk semantics) ----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    top_l, top_e = jax.lax.top_k(logits, k)  # (B, S, k)
    if cfg.moe_router_norm:
        top_w = jax.nn.softmax(top_l, axis=-1)
    else:
        top_w = jnp.take_along_axis(jax.nn.softmax(logits, axis=-1), top_e, axis=-1)

    # --- load-balancing auxiliary loss (Switch-style, no (…,E) one-hots) -----
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))  # (E,)
    bidx_e = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    counts = jnp.zeros((B, E), jnp.float32).at[bidx_e, top_e.reshape(B, S * k)].add(1.0)
    ce = jnp.sum(counts, axis=0) / (B * S * k)
    aux_loss = E * jnp.sum(me * ce)

    w_flat = top_w.reshape(B, S * k).astype(x.dtype)

    mesh, rules = active_context()
    baxes, maxes = _mesh_axes(B, mesh, rules)
    if mesh is None or (baxes is None and maxes is None):
        # local path (CPU tests / no mesh)
        dest, keep = _rank_and_dest(top_e, E, C, k)
        xe = _dispatch_local(x, dest, keep, E_local=E, C=C, k=k, e_offset=0)
        ye = _expert_ffn(p, xe, x.dtype)
        y = _combine_local(ye, dest, keep, w_flat, S=S, k=k, e_offset=0)
        return y, {"moe_aux_loss": aux_loss}

    n_model = 1
    if maxes:
        for a in maxes:
            n_model *= dict(mesh.shape)[a]
    if E % n_model:
        maxes, n_model = None, 1  # awkward expert count: replicate experts
    E_local = E // n_model
    bspec = baxes if baxes is not None else None

    def sharded_moe(x_l, top_e_l, w_flat_l, w_gate, w_up, w_down):
        # runs per (data, model) shard: x_l (b_loc, S, D) replicated over model
        if maxes:
            e_idx = jax.lax.axis_index(maxes[0])
            for a in maxes[1:]:
                e_idx = e_idx * dict(mesh.shape)[a] + jax.lax.axis_index(a)
        else:
            e_idx = 0
        e_off = e_idx * E_local
        dest, keep = _rank_and_dest(top_e_l, E, C, k)
        xe = _dispatch_local(x_l, dest, keep, E_local=E_local, C=C, k=k,
                             e_offset=e_off)
        ye = _expert_ffn({"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                         xe, x_l.dtype)
        y = _combine_local(ye, dest, keep, w_flat_l, S=S, k=k, e_offset=e_off)
        if maxes:
            y = jax.lax.psum(y, maxes)
        return y

    # expert weights enter sharded over (experts->model); other dims gathered
    wspec = P(maxes if maxes else None)
    y = jax.shard_map(
        sharded_moe, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec), wspec, wspec, wspec),
        out_specs=P(bspec),
        check_vma=False,
    )(x, top_e, w_flat,
      p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
      p["w_down"].astype(x.dtype))
    return y, {"moe_aux_loss": aux_loss}


def _expert_ffn(p, xe, dtype):
    """(b, E_l, C, D) -> (b, E_l, C, D) SwiGLU expert FFN (local shapes)."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(dtype)))
    h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(dtype))
    return jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dtype))


def _mesh_axes(B: int, mesh, rules):
    """(batch mesh axes, model/expert mesh axes) honoring divisibility."""
    if mesh is None or rules is None:
        return None, None
    bspec = spec_for((B,), ("batch",), rules, mesh)
    baxes = bspec[0] if len(bspec) else None
    sizes = dict(mesh.shape)
    maxes = tuple(a for a in rules.get("experts", ()) if a in sizes)
    return baxes, (maxes if maxes else None)
