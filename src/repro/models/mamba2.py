"""Mamba-2 (state-space duality / SSD) block, chunked TPU-friendly form.

The sequence is split into chunks of ``ssm_chunk``; the quadratic intra-chunk
part is a batched (attention-like) einsum that maps onto the MXU, and only the
tiny inter-chunk state recurrence (B, H, P, N) is a sequential scan — so the
heavy FLOPs stay outside ``lax.scan`` (correct cost accounting, full MXU
utilisation).  Decode is a single-step state update (O(1) per token, no KV
cache growth — this is why mamba2 runs the ``long_500k`` cell).

State cache layout: (conv_state (B, W-1, conv_ch), ssd_state (B, H, P, N)).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.parallel import make_param, shard


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim  # ssm heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_ch = d_inner + 2 * N  # x, B, C pass through the conv
    return d_inner, H, P, N, conv_ch


def init_mamba(key, cfg: ModelConfig, abstract=False):
    D = cfg.d_model
    d_inner, H, P, N, conv_ch = dims(cfg)
    ks = jax.random.split(key, 6) if key is not None else [None] * 6
    in_dim = 2 * d_inner + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": make_param(ks[0], (D, in_dim), ("embed", "heads"), cfg.param_dtype, abstract=abstract),
        "conv_w": make_param(ks[1], (cfg.ssm_conv_width, conv_ch), ("conv", None), cfg.param_dtype,
                             scale=1.0 / math.sqrt(cfg.ssm_conv_width), abstract=abstract),
        "conv_b": make_param(ks[1], (conv_ch,), (None,), cfg.param_dtype, init="zeros", abstract=abstract),
        "A_log": make_param(ks[2], (H,), (None,), "float32", init="zeros", abstract=abstract),
        "D_skip": make_param(ks[3], (H,), (None,), "float32", init="ones", abstract=abstract),
        "dt_bias": make_param(ks[4], (H,), (None,), "float32", init="zeros", abstract=abstract),
        "norm_scale": make_param(ks[5], (d_inner,), (None,), cfg.param_dtype, init="ones", abstract=abstract),
        "out_proj": make_param(ks[5], (d_inner, D), ("heads", "embed"), cfg.param_dtype,
                               scale=0.02 / math.sqrt(2 * cfg.num_layers), abstract=abstract),
    }


def _causal_conv(xBC, w, b, state: Optional[jax.Array]):
    """Depthwise causal conv, width W.  xBC: (B,S,ch); state: (B,W-1,ch)|None.

    Returns (out (B,S,ch), new_state)."""
    W = w.shape[0]
    B, S, ch = xBC.shape
    if state is None:
        pad = jnp.zeros((B, W - 1, ch), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)  # (B, S+W-1, ch)
    out = jnp.zeros((B, S, ch), jnp.float32)
    for i in range(W):  # W=4: tiny static unroll
        out = out + full[:, i : i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = jax.nn.silu(out + b.astype(jnp.float32)).astype(xBC.dtype)
    new_state = full[:, S:, :] if S >= W - 1 else jnp.concatenate([pad, xBC], axis=1)[:, -(W - 1):, :]
    return out, new_state


def _segsum(log_a):
    """log_a: (..., Q) -> (..., Q, Q) lower-triangular cumulative sums
    L[q, s] = sum_{t=s+1..q} log_a_t (for s <= q)."""
    c = jnp.cumsum(log_a, axis=-1)
    diff = c[..., :, None] - c[..., None, :]  # (..., q, s)
    Q = log_a.shape[-1]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bm/Cm: (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        # pad to a chunk multiple: dt=0 -> decay 1, input 0 (state-neutral)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, h = ssd_chunked(x, dt, A, Bm, Cm, Q, initial_state)
        return y[:, :S], h
    nc = S // Q

    dtf = dt.astype(jnp.float32)
    log_a = dtf * A  # (B,S,H), negative
    xw = (x.astype(jnp.float32) * dtf[..., None])  # dt-weighted inputs

    # reshape into chunks
    la = log_a.reshape(B, nc, Q, H)
    xc = xw.reshape(B, nc, Q, H, P)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, Q, N)

    # ---- intra-chunk (quadratic, vectorised over chunks) --------------------
    Lmat = jnp.exp(_segsum(jnp.moveaxis(la, -1, -2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)[:, :, None] * Lmat  # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", scores, xc)

    # ---- chunk states --------------------------------------------------------
    la_sum = jnp.sum(la, axis=2)  # (B,nc,H) total decay per chunk
    decay_to_end = jnp.exp(la_sum[:, :, None, :] - jnp.cumsum(la, axis=2))  # (B,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_to_end, xc)  # (B,nc,H,P,N)

    # ---- inter-chunk recurrence (small sequential scan) ----------------------
    if initial_state is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    def step(h, inp):
        s_c, a_c = inp  # (B,H,P,N), (B,H)
        h_prev = h
        h = h * jnp.exp(a_c)[:, :, None, None] + s_c
        return h, h_prev

    states_t = jnp.moveaxis(states, 1, 0)  # (nc,B,H,P,N)
    la_sum_t = jnp.moveaxis(la_sum, 1, 0)  # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(step, h0, (states_t, la_sum_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N) state entering chunk

    # ---- inter-chunk contribution -------------------------------------------
    decay_from_start = jnp.exp(jnp.cumsum(la, axis=2))  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_from_start, h_prevs)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, h_final


def ssd_decode_step(x, dt, A, Bm, Cm, state):
    """Single-token SSD update. x: (B,1,H,P); state: (B,H,P,N)."""
    B = x.shape[0]
    dtf = dt.astype(jnp.float32)[:, 0]  # (B,H)
    a = jnp.exp(dtf * A)  # (B,H)
    xw = x.astype(jnp.float32)[:, 0] * dtf[..., None]  # (B,H,P)
    Bv = Bm.astype(jnp.float32)[:, 0]  # (B,N)
    Cv = Cm.astype(jnp.float32)[:, 0]
    new_state = state * a[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", xw, Bv)
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cv)
    return y[:, None], new_state  # (B,1,H,P)


def apply_mamba(p, u, cfg: ModelConfig, cache=None):
    """u: (B,S,D). cache: (conv_state, ssd_state) or None.

    Returns (out (B,S,D), new_cache)."""
    B, S, D = u.shape
    d_inner, H, P, N, conv_ch = dims(cfg)
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch :]  # (B,S,H)

    conv_state = cache[0] if cache is not None else None
    xBC, new_conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    x = xBC[..., :d_inner].reshape(B, S, H, P)
    Bm = xBC[..., d_inner : d_inner + N]
    Cm = xBC[..., d_inner + N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is not None and S == 1:
        y, new_state = ssd_decode_step(x, dt, A, Bm, Cm, cache[1])
    else:
        init_state = cache[1] if cache is not None else None
        y, new_state = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)

    y = y + x.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(u.dtype)

    # gated RMSNorm (mamba2: norm(y * silu(z)))
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)).astype(u.dtype)

    out = g @ p["out_proj"].astype(u.dtype)
    new_cache = (new_conv_state, new_state) if cache is not None else None
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_inner, H, P, N, conv_ch = dims(cfg)
    conv_state = jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype)
    ssd_state = jnp.zeros((batch, H, P, N), jnp.float32)
    return conv_state, ssd_state
