"""Generic multi-family transformer stack.

One code path covers all 10 assigned architectures via ``layer_pattern``
chars: G (global attention), L (local / sliding-window attention),
M (Mamba-2 SSD), R (RG-LRU recurrent).  Layers are grouped into one copy of
the pattern and the group stack is evaluated with ``lax.scan`` over stacked
parameters (HLO size independent of depth).  A non-divisible remainder
("tail") is applied unscanned so e.g. recurrentgemma's 38 = 12x(RRL) + RR
is exact.

The same group-apply function is reused by (a) full forward, (b) the
split-learning client/server partition (slicing the stacked group params),
and (c) the roofline calibration lowering (single group, loop-free).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.parallel import ParamLeaf, make_param, shard, split_param_tree

# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------


def group_chars(cfg: ModelConfig) -> str:
    return cfg.layer_pattern


def n_full_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(cfg.layer_pattern)


def tail_chars(cfg: ModelConfig) -> str:
    rem = cfg.num_layers % len(cfg.layer_pattern)
    return cfg.layer_pattern[:rem]


def _char_window(cfg: ModelConfig, ch: str) -> int:
    if ch == "L":
        return cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# Single layer (one pattern char)
# ---------------------------------------------------------------------------


def init_sublayer(key, cfg: ModelConfig, ch: str, abstract=False, cross_attn=False):
    ks = jax.random.split(key, 6) if key is not None else [None] * 6
    p: dict[str, Any] = {"norm1": L.init_norm(ks[0], cfg, cfg.d_model, abstract=abstract)}
    if ch in ("G", "L"):
        p["attn"] = L.init_attn(ks[1], cfg, abstract=abstract)
        if cross_attn:
            p["norm_x"] = L.init_norm(ks[2], cfg, cfg.d_model, abstract=abstract)
            p["xattn"] = L.init_attn(ks[3], cfg, abstract=abstract)
        if not cfg.parallel_block:
            p["norm2"] = L.init_norm(ks[2], cfg, cfg.d_model, abstract=abstract)
        if cfg.use_post_norm:
            p["post_norm1"] = L.init_norm(ks[4], cfg, cfg.d_model, abstract=abstract)
            p["post_norm2"] = L.init_norm(ks[4], cfg, cfg.d_model, abstract=abstract)
        if cfg.num_experts:
            p["moe"] = MOE.init_moe(ks[5], cfg, abstract=abstract)
        else:
            p["mlp"] = L.init_mlp(ks[5], cfg, abstract=abstract)
    elif ch == "M":
        p["mamba"] = M2.init_mamba(ks[1], cfg, abstract=abstract)
    elif ch == "R":
        p["rglru"] = RG.init_rglru_block(ks[1], cfg, abstract=abstract)
        p["norm2"] = L.init_norm(ks[2], cfg, cfg.d_model, abstract=abstract)
        p["mlp"] = L.init_mlp(ks[5], cfg, abstract=abstract)
    else:
        raise ValueError(ch)
    return p


def apply_sublayer(
    p,
    x,
    cfg: ModelConfig,
    ch: str,
    *,
    cache=None,
    cache_pos=None,
    positions=None,
    causal=True,
    enc_out=None,
    q_chunk=0,
    unroll_chunks=False,
):
    """Apply one layer. Returns (x, new_cache, aux)."""
    aux = {}
    new_cache: Any = None
    if ch in ("G", "L"):
        window = _char_window(cfg, ch)
        h = L.apply_norm(p["norm1"], x, cfg)
        attn_cache = cache.get("attn") if cache else None
        a, new_attn_cache = L.attention(
            p["attn"], h, cfg, window=window, positions=positions, cache=attn_cache,
            cache_pos=cache_pos, q_chunk=q_chunk, unroll_chunks=unroll_chunks,
            causal=causal,
        )
        if cfg.use_post_norm:
            a = L.apply_norm(p["post_norm1"], a, cfg)
        if cfg.parallel_block:
            # command-r: attn and mlp both read norm1 output, summed residual
            m = L.apply_mlp(p["mlp"], h, cfg) if "mlp" in p else None
            if m is None:
                m, aux = MOE.apply_moe(p["moe"], h, cfg)
            x = x + a + m
            new_cache = {"attn": new_attn_cache} if new_attn_cache is not None else None
            return x, new_cache, aux
        x = x + a
        if "xattn" in p and enc_out is not None:
            hx = L.apply_norm(p["norm_x"], x, cfg)
            # the cross-KV cache is only valid for decode (q_len == 1);
            # prefill recomputes it from the encoder output and stores it
            cached_cross = cache.get("cross") if (cache and x.shape[1] == 1) else None
            xa, new_x_cache = _cross_attention(p["xattn"], hx, enc_out, cfg,
                                               cached_cross)
            x = x + xa
        else:
            new_x_cache = None
        h2 = L.apply_norm(p["norm2"], x, cfg)
        if "moe" in p:
            m, aux = MOE.apply_moe(p["moe"], h2, cfg)
        else:
            m = L.apply_mlp(p["mlp"], h2, cfg)
        if cfg.use_post_norm:
            m = L.apply_norm(p["post_norm2"], m, cfg)
        x = x + m
        c = {}
        if new_attn_cache is not None:
            c["attn"] = new_attn_cache
        if new_x_cache is not None:
            c["cross"] = new_x_cache
        new_cache = c or None
    elif ch == "M":
        h = L.apply_norm(p["norm1"], x, cfg)
        m_cache = cache.get("ssm") if cache else None
        y, new_m = M2.apply_mamba(p["mamba"], h, cfg, cache=m_cache)
        x = x + y
        new_cache = {"ssm": new_m} if new_m is not None else None
    elif ch == "R":
        h = L.apply_norm(p["norm1"], x, cfg)
        r_cache = cache.get("rec") if cache else None
        y, new_r = RG.apply_rglru_block(p["rglru"], h, cfg, cache=r_cache)
        x = x + y
        h2 = L.apply_norm(p["norm2"], x, cfg)
        x = x + L.apply_mlp(p["mlp"], h2, cfg)
        new_cache = {"rec": new_r} if new_r is not None else None
    else:
        raise ValueError(ch)
    return x, new_cache, aux


def _cross_attention(p, x, enc_out, cfg: ModelConfig, cached_kv):
    """Cross-attention: q from x, k/v from encoder output (or cache)."""
    B, S, D = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    if cached_kv is not None:
        k, v = cached_kv
        k = k.astype(x.dtype)
        v = v.astype(x.dtype)
        new_kv = cached_kv
    else:
        k = (enc_out @ p["wk"].astype(x.dtype)).reshape(B, -1, Kv, hd)
        v = (enc_out @ p["wv"].astype(x.dtype)).reshape(B, -1, Kv, hd)
        new_kv = (k, v)
    out = L._attend_full(q, k, v, causal=False, window=0, softcap=0.0)
    return out @ p["wo"].astype(x.dtype), new_kv


# ---------------------------------------------------------------------------
# Group (one copy of the pattern) — the scan body
# ---------------------------------------------------------------------------


def init_group(key, cfg: ModelConfig, abstract=False, cross_attn=False):
    chars = group_chars(cfg)
    ks = jax.random.split(key, len(chars)) if key is not None else [None] * len(chars)
    return {f"sub_{i}": init_sublayer(ks[i], cfg, ch, abstract=abstract, cross_attn=cross_attn)
            for i, ch in enumerate(chars)}


def apply_group(gp, x, cfg: ModelConfig, *, chars=None, cache=None, cache_pos=None,
                positions=None, causal=True, enc_out=None, q_chunk=0, unroll_chunks=False):
    chars = chars or group_chars(cfg)
    new_cache = {}
    aux_total = None
    for i, ch in enumerate(chars):
        sub_cache = cache.get(f"sub_{i}") if cache else None
        x, nc, aux = apply_sublayer(
            gp[f"sub_{i}"], x, cfg, ch, cache=sub_cache, cache_pos=cache_pos,
            positions=positions, causal=causal, enc_out=enc_out,
            q_chunk=q_chunk, unroll_chunks=unroll_chunks,
        )
        if nc is not None:
            new_cache[f"sub_{i}"] = nc
        if aux:
            aux_total = aux if aux_total is None else jax.tree.map(lambda a, b: a + b, aux_total, aux)
    return x, (new_cache or None), (aux_total or {})


# ---------------------------------------------------------------------------
# Full model params
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key=None, abstract=False):
    """Returns (values_tree, axes_tree). With abstract=True, leaves are
    ShapeDtypeStructs (no allocation — used by the dry-run)."""
    if key is None and not abstract:
        key = jax.random.PRNGKey(0)
    nk = 8
    ks = jax.random.split(key, nk) if key is not None else [None] * nk

    tree: dict[str, Any] = {"embed": L.init_embed(ks[0], cfg, abstract=abstract)}
    ng = n_full_groups(cfg)
    cross = cfg.family == "encdec"

    # stacked groups
    if abstract:
        one = init_group(None, cfg, abstract=True, cross_attn=cross)
        stacked = jax.tree.map(
            lambda p: ParamLeaf(jax.ShapeDtypeStruct((ng,) + p.value.shape, p.value.dtype),
                                ("layers",) + p.axes),
            one, is_leaf=lambda t: isinstance(t, ParamLeaf))
    else:
        gkeys = jax.random.split(ks[1], ng)

        def mk(k):
            return split_param_tree(init_group(k, cfg, cross_attn=cross))[0]

        vals = jax.vmap(mk)(gkeys)
        axes = split_param_tree(init_group(jax.random.PRNGKey(0), cfg, cross_attn=cross))[1]
        stacked = jax.tree.map(lambda v, a: ParamLeaf(v, ("layers",) + a), vals, axes,
                               is_leaf=lambda t: isinstance(t, tuple) and not isinstance(t, ParamLeaf) and all(isinstance(e, (str, type(None))) for e in t))
    tree["groups"] = stacked

    # unscanned tail layers
    tchars = tail_chars(cfg)
    if tchars:
        tkeys = jax.random.split(ks[2], len(tchars)) if not abstract else [None] * len(tchars)
        for i, ch in enumerate(tchars):
            tree[f"tail_{i}"] = init_sublayer(tkeys[i], cfg, ch, abstract=abstract, cross_attn=cross)

    tree["final_norm"] = L.init_norm(ks[3], cfg, cfg.d_model, abstract=abstract)

    if cfg.family == "encdec":
        eng = cfg.num_encoder_layers
        if abstract:
            eone = init_group(None, cfg.replace(layer_pattern="G"), abstract=True)
            tree["enc_groups"] = jax.tree.map(
                lambda p: ParamLeaf(jax.ShapeDtypeStruct((eng,) + p.value.shape, p.value.dtype),
                                    ("layers",) + p.axes),
                eone, is_leaf=lambda t: isinstance(t, ParamLeaf))
        else:
            ekeys = jax.random.split(ks[4], eng)

            def mke(k):
                return split_param_tree(init_group(k, cfg.replace(layer_pattern="G")))[0]

            evals = jax.vmap(mke)(ekeys)
            eaxes = split_param_tree(init_group(jax.random.PRNGKey(0), cfg.replace(layer_pattern="G")))[1]
            tree["enc_groups"] = jax.tree.map(lambda v, a: ParamLeaf(v, ("layers",) + a), evals, eaxes,
                                              is_leaf=lambda t: isinstance(t, tuple) and not isinstance(t, ParamLeaf) and all(isinstance(e, (str, type(None))) for e in t))
        tree["enc_final_norm"] = L.init_norm(ks[5], cfg, cfg.d_model, abstract=abstract)
        # learned positional embeddings (whisper style)
        tree["enc_pos"] = make_param(ks[5], (cfg.encoder_seq, cfg.d_model), (None, "embed"),
                                     cfg.param_dtype, abstract=abstract)
        tree["dec_pos"] = make_param(ks[6], (32768, cfg.d_model), (None, "embed"),
                                     cfg.param_dtype, abstract=abstract)

    if cfg.family == "vlm":
        vd = 1024  # vision encoder width (CLIP-L); frontend itself is a stub
        tree["projector"] = {
            "w1": make_param(ks[4], (vd, cfg.d_model), (None, "embed"), cfg.param_dtype, abstract=abstract),
            "b1": make_param(ks[4], (cfg.d_model,), ("embed",), cfg.param_dtype, init="zeros", abstract=abstract),
            "w2": make_param(ks[5], (cfg.d_model, cfg.d_model), ("embed", "embed"), cfg.param_dtype, abstract=abstract),
            "b2": make_param(ks[5], (cfg.d_model,), ("embed",), cfg.param_dtype, init="zeros", abstract=abstract),
        }

    return split_param_tree(tree)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token (+modality-stub) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        pj = params["projector"]
        v = batch["vision_embeds"].astype(cfg.dtype)
        v = jax.nn.gelu(v @ pj["w1"].astype(v.dtype) + pj["b1"], approximate=True)
        v = v @ pj["w2"].astype(v.dtype) + pj["b2"]
        v = shard(v, ("batch", "seq", "embed"))
        x = jnp.concatenate([v, x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    if cfg.family == "encdec":
        x = x + params["dec_pos"][None, :S].astype(x.dtype)
    return x, positions


def _run_encoder(params, batch, cfg: ModelConfig):
    frames = batch["frame_embeds"].astype(cfg.dtype)  # stub: precomputed
    Senc = frames.shape[1]
    x = frames + params["enc_pos"][None, :Senc].astype(frames.dtype)
    ecfg = cfg.replace(layer_pattern="G", use_rope=False)

    def body(h, gp):
        h, _, _ = apply_group(gp, h, ecfg, chars="G", causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_groups"])
    return L.apply_norm(params["enc_final_norm"], x, cfg)


def _scan_groups(params, x, cfg: ModelConfig, *, cache=None, cache_pos=None,
                 positions=None, enc_out=None, q_chunk=0, remat=False,
                 groups_slice=None, include_tail=True, unroll=False):
    """Run the scanned group stack (+ tail). cache is threaded through scan."""
    gparams = params["groups"] if groups_slice is None else groups_slice

    if cache is None:
        def body(carry, gp):
            h = carry
            h, _, aux = apply_group(gp, h, cfg, cache=None, cache_pos=cache_pos,
                                    positions=positions, enc_out=enc_out, q_chunk=q_chunk)
            return h, aux.get("moe_aux_loss", jnp.zeros((), jnp.float32))

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, aux_stack = jax.lax.scan(body, x, gparams, unroll=unroll)
        aux_total = jnp.sum(aux_stack)
        new_cache = None
    else:
        # Cache rides in the scan CARRY as one stacked buffer updated with
        # dynamic_update_index_in_dim — threading it through xs/ys made XLA
        # materialise a full cache copy per step (§Perf iter: decode temp
        # bytes 151 GB vs the 21.5 GB cache on command-r decode_32k).
        ng = jax.tree.leaves(gparams)[0].shape[0]

        def body(carry, xs):
            h, cache_all = carry
            gp, i = xs
            gc = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                              cache_all)
            h, new_c, aux = apply_group(gp, h, cfg, cache=gc, cache_pos=cache_pos,
                                        positions=positions, enc_out=enc_out,
                                        q_chunk=q_chunk)
            cache_all = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u.astype(a.dtype), i, 0),
                cache_all, new_c)
            return (h, cache_all), aux.get("moe_aux_loss", jnp.zeros((), jnp.float32))

        (x, new_group_cache), aux_stack = jax.lax.scan(
            body, (x, cache["groups"]), (gparams, jnp.arange(ng)), unroll=unroll)
        aux_total = jnp.sum(aux_stack)
        new_cache = {"groups": new_group_cache}
    # tail layers (unscanned)
    tchars = tail_chars(cfg) if include_tail else ""
    for i, ch in enumerate(tchars):
        tc = cache.get(f"tail_{i}") if cache else None
        x, nc, aux = apply_sublayer(params[f"tail_{i}"], x, cfg, ch, cache=tc,
                                    cache_pos=cache_pos, positions=positions,
                                    enc_out=enc_out, q_chunk=q_chunk)
        if cache is not None:
            new_cache[f"tail_{i}"] = nc
        if aux:
            aux_total = aux_total + aux.get("moe_aux_loss", 0.0)
    return x, new_cache, aux_total


def forward(params, batch, cfg: ModelConfig, *, kind: str = "train",
            q_chunk: int = 0, remat: bool = False, unroll: bool = False):
    """Full forward -> logits (B, S, V). kind: train|prefill."""
    enc_out = _run_encoder(params, batch, cfg) if cfg.family == "encdec" else None
    x, positions = _embed_inputs(params, batch, cfg)
    if q_chunk == 0 and x.shape[1] >= 16384:
        q_chunk = 2048
    x, _, aux = _scan_groups(params, x, cfg, positions=positions, enc_out=enc_out,
                             q_chunk=q_chunk, remat=remat, unroll=unroll)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits, aux


def hidden_states(params, batch, cfg: ModelConfig, *, q_chunk: int = 0,
                  remat: bool = False, unroll: bool = False):
    """Forward up to the final norm (pre-logits). Returns (x, aux)."""
    enc_out = _run_encoder(params, batch, cfg) if cfg.family == "encdec" else None
    x, positions = _embed_inputs(params, batch, cfg)
    if q_chunk == 0 and x.shape[1] >= 16384:
        q_chunk = 2048
    x, _, aux = _scan_groups(params, x, cfg, positions=positions, enc_out=enc_out,
                             q_chunk=q_chunk, remat=remat, unroll=unroll)
    return L.apply_norm(params["final_norm"], x, cfg), aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = False, aux_weight=0.01,
            unroll: bool = False):
    """Training loss with sequence-chunked CE (the full (B,S,V) fp32 logits
    tensor never materialises — §Perf iter 5)."""
    x, aux = hidden_states(params, batch, cfg, remat=remat, unroll=unroll)
    loss = L.fused_cross_entropy(params["embed"], x, batch["labels"], cfg,
                                 mask=batch.get("mask"), unroll=unroll)
    return loss + aux_weight * aux, {"ce_loss": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------


def _sublayer_cache(cfg: ModelConfig, ch: str, batch: int, max_seq: int, dtype,
                    cross: bool = False):
    if ch in ("G", "L"):
        window = _char_window(cfg, ch)
        S_c = min(window, max_seq) if window else max_seq
        kv = {
            "attn": (
                jnp.zeros((batch, S_c, cfg.num_kv_heads, cfg.head_dim), dtype),
                jnp.zeros((batch, S_c, cfg.num_kv_heads, cfg.head_dim), dtype),
            )
        }
        if cross:
            kv["cross"] = (
                jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
                jnp.zeros((batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
            )
        return kv
    if ch == "M":
        return {"ssm": M2.init_mamba_cache(cfg, batch, dtype)}
    if ch == "R":
        return {"rec": RG.init_rglru_cache(cfg, batch, dtype)}
    raise ValueError(ch)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cross = cfg.family == "encdec"
    ng = n_full_groups(cfg)
    one = {f"sub_{i}": _sublayer_cache(cfg, ch, batch, max_seq, dtype, cross)
           for i, ch in enumerate(group_chars(cfg))}
    groups = jax.tree.map(lambda a: jnp.broadcast_to(a, (ng,) + a.shape), one)
    cache = {"groups": groups}
    for i, ch in enumerate(tail_chars(cfg)):
        cache[f"tail_{i}"] = _sublayer_cache(cfg, ch, batch, max_seq, dtype, cross)
    return cache


def cache_axes(cache):
    """Logical sharding axes for a cache tree (matched by rank)."""

    def one(a):
        if a.ndim == 5:  # (layers, B, S, Kv, hd)
            return ("layers", "batch", "kv_seq", "kv_heads", None)
        if a.ndim == 4:  # stacked conv/ssd states
            return ("layers", "batch", None, None)
        if a.ndim == 3:
            return ("layers", "batch", None)
        if a.ndim == 2:
            return ("batch", None)
        return tuple([None] * a.ndim)

    return jax.tree.map(one, cache)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(params, tokens, cache, cache_pos, cfg: ModelConfig, enc_out=None,
                unroll: bool = False):
    """One-token decode. tokens: (B, 1). Returns (logits (B,1,V), new_cache)."""
    x = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "encdec":
        x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], cache_pos, 1, axis=0)[None].astype(x.dtype)
    positions = jnp.full((tokens.shape[0], 1), cache_pos, dtype=jnp.int32)
    x, new_cache, _ = _scan_groups(params, x, cfg, cache=cache, cache_pos=cache_pos,
                                   positions=positions, enc_out=enc_out, unroll=unroll)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits, new_cache


def prefill(params, batch, cfg: ModelConfig, cache, unroll: bool = False):
    """Prefill: run full sequence, writing the cache. Returns (logits, cache)."""
    enc_out = _run_encoder(params, batch, cfg) if cfg.family == "encdec" else None
    x, positions = _embed_inputs(params, batch, cfg)
    q_chunk = 2048 if x.shape[1] >= 16384 else 0
    x, new_cache, _ = _scan_groups(params, x, cfg, cache=cache, cache_pos=jnp.array(0, jnp.int32),
                                   positions=positions, enc_out=enc_out, q_chunk=q_chunk,
                                   unroll=unroll)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.lm_logits(params["embed"], x, cfg)
    return logits, new_cache
