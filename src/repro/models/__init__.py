from repro.models.registry import build_model, count_params

__all__ = ["build_model", "count_params"]
