"""Shared transformer layers: norms, RoPE, GQA attention (global / sliding
window / softcap / qk-norm), MLP variants, embeddings and logit heads.

All parameters are declared through ``make_param`` so every leaf carries its
logical sharding axes.  All functions are pure; attention supports three
modes: full-sequence (train / prefill), block-banded local attention, and
single-step decode against a (possibly ring-buffer) KV cache.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.parallel import make_param, shard

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(key, cfg: ModelConfig, dim: int, prefix=(), abstract=False):
    p = {"scale": make_param(key, (dim,), ("embed",), cfg.param_dtype, init="ones", abstract=abstract)}
    if cfg.norm_type == "layernorm" and cfg.use_bias:
        p["bias"] = make_param(key, (dim,), ("embed",), cfg.param_dtype, init="zeros", abstract=abstract)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_only(w, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Per-layer-stack KV cache.

    k/v: (groups, B, S_cache, kv_heads, head_dim) — stacked over scan groups.
    For sliding-window layers S_cache = window (ring buffer addressed by
    ``pos % window``); for global layers S_cache = max_seq.
    """

    k: jax.Array
    v: jax.Array


def init_attn(key, cfg: ModelConfig, prefix="attn", abstract=False):
    D, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8) if key is not None else [None] * 8
    scale = 0.02
    p = {
        "wq": make_param(ks[0], (D, H * hd), ("embed", "heads"), cfg.param_dtype, scale=scale, abstract=abstract),
        "wk": make_param(ks[1], (D, Kv * hd), ("embed", "kv_heads"), cfg.param_dtype, scale=scale, abstract=abstract),
        "wv": make_param(ks[2], (D, Kv * hd), ("embed", "kv_heads"), cfg.param_dtype, scale=scale, abstract=abstract),
        "wo": make_param(ks[3], (H * hd, D), ("heads", "embed"), cfg.param_dtype, scale=scale / math.sqrt(2 * cfg.num_layers), abstract=abstract),
    }
    if cfg.use_bias:
        p["bq"] = make_param(ks[4], (H * hd,), ("heads",), cfg.param_dtype, init="zeros", abstract=abstract)
        p["bk"] = make_param(ks[5], (Kv * hd,), ("kv_heads",), cfg.param_dtype, init="zeros", abstract=abstract)
        p["bv"] = make_param(ks[6], (Kv * hd,), ("kv_heads",), cfg.param_dtype, init="zeros", abstract=abstract)
        p["bo"] = make_param(ks[7], (D,), ("embed",), cfg.param_dtype, init="zeros", abstract=abstract)
    if cfg.qk_norm:
        p["q_norm"] = make_param(ks[4], (hd,), (None,), cfg.param_dtype, init="ones", abstract=abstract)
        p["k_norm"] = make_param(ks[5], (hd,), (None,), cfg.param_dtype, init="ones", abstract=abstract)
    return p


def _softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def _attend_full(q, k, v, *, causal: bool, window: int, softcap: float,
                 q_offset: jax.Array | int = 0, kv_offset: jax.Array | int = 0):
    """Dense masked attention. q: (B,Sq,H,hd); k/v: (B,Skv,Kv,hd)."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    qh = q.reshape(B, Sq, Kv, rep, hd)
    logits = jnp.einsum("bqkrh,bskh->bkrqs", qh, k, preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(hd)
    logits = _softcap(logits, softcap)
    qpos = jnp.arange(Sq) + q_offset  # absolute positions
    kpos = jnp.arange(k.shape[1]) + kv_offset
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, v)
    return out.reshape(B, Sq, H * hd)


def _attend_banded(q, k, v, *, window: int, softcap: float):
    """Block-banded sliding-window attention: exact for causal window ≤ block.

    Splits seq into blocks of ``window``; block i attends to blocks {i-1, i}.
    Flops O(S·2w·hd) instead of O(S²·hd).
    """
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    assert S % window == 0, (S, window)
    nb = S // window
    rep = H // Kv
    qb = q.reshape(B, nb, window, Kv, rep, hd)
    kb = k.reshape(B, nb, window, Kv, hd)
    vb = v.reshape(B, nb, window, Kv, hd)
    # previous block (block -1 = zeros, masked out anyway)
    kprev = jnp.pad(kb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([kprev, kb], axis=2)  # (B,nb,2w,Kv,hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    logits = jnp.einsum("bnqkrh,bnskh->bnkrqs", qb, k2, preferred_element_type=jnp.float32)
    logits = _softcap(logits / math.sqrt(hd), softcap)
    qpos = jnp.arange(window)[:, None]  # within-block index
    kpos = jnp.arange(2 * window)[None, :] - window  # relative to block start
    mask = (kpos <= qpos) & (kpos > qpos - window)
    first_block = jnp.arange(nb) == 0  # block 0 has no prev block
    mask_full = mask[None, :, :] & ~(first_block[:, None, None] & (kpos[None] < 0))
    logits = jnp.where(mask_full[None, :, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkrqs,bnskh->bnqkrh", probs, v2)
    return out.reshape(B, S, H * hd)


def _attend_chunked_q(q, k, v, *, causal: bool, window: int, softcap: float,
                      chunk: int, unroll: bool = False):
    """Query-chunked attention (bounds logits memory to S·chunk per head).

    Used for long prefill.  The KV tensors stay whole (flash-style online
    softmax lives in the Pallas kernel; this jnp path chunks queries only,
    which is enough to bound memory since kv is shared)."""
    B, S, H, hd = q.shape
    nq = S // chunk

    def one(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        return _attend_full(qs, k, v, causal=causal, window=window, softcap=softcap,
                            q_offset=i * chunk, kv_offset=0)

    if unroll:
        outs = [one(i) for i in range(nq)]
        return jnp.concatenate(outs, axis=1)
    outs = jax.lax.map(one, jnp.arange(nq))  # (nq, B, chunk, H*hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H * hd)


def attention(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    window: int = 0,
    positions: Optional[jax.Array] = None,
    cache: Optional[tuple[jax.Array, jax.Array]] = None,
    cache_pos: Optional[jax.Array] = None,
    q_chunk: int = 0,
    unroll_chunks: bool = False,
    causal: bool = True,
):
    """GQA attention. Returns (out, new_cache_kv or None).

    cache: (k, v) each (B, S_cache, Kv, hd); decode mode when x seq==1 (or
    small) and cache is given; cache_pos = current absolute position (int32
    scalar array).
    """
    B, S, D = x.shape
    H, Kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Kv, hd)
    v = v.reshape(B, S, Kv, hd)
    if cfg.qk_norm:
        q = rms_norm_only(p["q_norm"], q)
        k = rms_norm_only(p["k_norm"], k)
    if positions is None:
        positions = jnp.arange(S)[None, :] + (0 if cache_pos is None else cache_pos)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q = shard(q, ("batch", "seq", "heads", None))
    k = shard(k, ("batch", "seq", "kv_heads", None))
    v = shard(v, ("batch", "seq", "kv_heads", None))

    new_cache = None
    if cache is not None and S == 1:
        # ---- decode: single token vs cache --------------------------------
        ck, cv = cache
        S_cache = ck.shape[1]
        if window and window > 0 and S_cache == window:
            # ring buffer: overwrite slot pos % window
            slot = jnp.mod(cache_pos, window)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
            kpos_abs = _ring_positions(cache_pos, window)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cache_pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cache_pos, axis=1)
            kpos_abs = None
        ck = shard(ck, ("batch", "kv_seq", "kv_heads", None))
        cv = shard(cv, ("batch", "kv_seq", "kv_heads", None))
        new_cache = (ck, cv)
        out = _decode_attend(q, ck, cv, cfg=cfg, window=window, cache_pos=cache_pos,
                             kpos_abs=kpos_abs)
    elif cache is not None:
        # ---- prefill: attend with in-flight k/v, write the cache ----------
        ck, cv = cache
        S_cache = ck.shape[1]
        if S >= S_cache:
            # ring-buffer (or exactly-full) cache keeps the last S_cache keys;
            # slot layout matches _ring_positions when S % S_cache == 0
            ck = k[:, S - S_cache:].astype(ck.dtype)
            cv = v[:, S - S_cache:].astype(cv.dtype)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), 0, axis=1)
        ck = shard(ck, ("batch", "kv_seq", "kv_heads", None))
        cv = shard(cv, ("batch", "kv_seq", "kv_heads", None))
        new_cache = (ck, cv)
        if causal and window and window > 0 and S % window == 0 and S > window:
            out = _attend_banded(q, k, v, window=window, softcap=cfg.attn_logit_softcap)
        elif q_chunk and S > q_chunk:
            out = _attend_chunked_q(q, k, v, causal=causal, window=window,
                                    softcap=cfg.attn_logit_softcap, chunk=q_chunk,
                                    unroll=unroll_chunks)
        else:
            out = _attend_full(q, k, v, causal=causal, window=window,
                               softcap=cfg.attn_logit_softcap)
    else:
        if causal and window and window > 0 and S % window == 0 and S > window:
            out = _attend_banded(q, k, v, window=window, softcap=cfg.attn_logit_softcap)
        elif q_chunk and S > q_chunk:
            out = _attend_chunked_q(q, k, v, causal=causal, window=window,
                                    softcap=cfg.attn_logit_softcap, chunk=q_chunk,
                                    unroll=unroll_chunks)
        else:
            out = _attend_full(q, k, v, causal=causal, window=window,
                               softcap=cfg.attn_logit_softcap)
    out = shard(out, ("batch", "seq", "heads"))
    y = out @ p["wo"].astype(x.dtype)
    if "bo" in p:
        y = y + p["bo"]
    return y, new_cache


def _ring_positions(cache_pos, window):
    """Absolute positions stored in each ring-buffer slot after writing at
    slot = cache_pos % window.  Slot j holds position: the largest p <= cache_pos
    with p % window == j."""
    slots = jnp.arange(window)
    cur = jnp.mod(cache_pos, window)
    base = cache_pos - cur
    pos = jnp.where(slots <= cur, base + slots, base - window + slots)
    return pos  # (window,) may be negative for not-yet-written slots


def _decode_attend(q, ck, cv, *, cfg: ModelConfig, window: int, cache_pos, kpos_abs):
    """q: (B,1,H,hd) vs cache (B,Sc,Kv,hd)."""
    B, Sq, H, hd = q.shape
    Kv = ck.shape[2]
    rep = H // Kv
    qh = q.reshape(B, Sq, Kv, rep, hd)
    logits = jnp.einsum("bqkrh,bskh->bkrqs", qh, ck.astype(q.dtype),
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    logits = _softcap(logits, cfg.attn_logit_softcap)
    if kpos_abs is not None:  # ring buffer
        valid = (kpos_abs >= 0) & (kpos_abs <= cache_pos)
        if window:
            valid &= kpos_abs > cache_pos - window
        mask = valid[None, None, None, None, :]
    else:
        kpos = jnp.arange(ck.shape[1])
        valid = kpos <= cache_pos
        if window and window > 0:
            valid &= kpos > cache_pos - window
        mask = valid[None, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrqs,bskh->bqkrh", probs, cv.astype(q.dtype))
    return out.reshape(B, Sq, H * hd)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, abstract=False):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3) if key is not None else [None] * 3
    act = cfg.mlp_activation
    p = {}
    if act in ("swiglu", "geglu"):
        p["w_gate"] = make_param(ks[0], (D, F), ("embed", "mlp"), cfg.param_dtype, abstract=abstract)
        p["w_up"] = make_param(ks[1], (D, F), ("embed", "mlp"), cfg.param_dtype, abstract=abstract)
    else:
        p["w_up"] = make_param(ks[1], (D, F), ("embed", "mlp"), cfg.param_dtype, abstract=abstract)
        if cfg.use_bias:
            p["b_up"] = make_param(ks[1], (F,), ("mlp",), cfg.param_dtype, init="zeros", abstract=abstract)
    p["w_down"] = make_param(ks[2], (F, D), ("mlp", "embed"), cfg.param_dtype,
                             scale=0.02 / math.sqrt(2 * cfg.num_layers), abstract=abstract)
    if cfg.use_bias:
        p["b_down"] = make_param(ks[2], (D,), ("embed",), cfg.param_dtype, init="zeros", abstract=abstract)
    return p


def apply_mlp(p, x, cfg: ModelConfig):
    act = cfg.mlp_activation
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype), approximate=True) * (x @ p["w_up"].astype(x.dtype))
    else:
        h = x @ p["w_up"].astype(x.dtype)
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h, approximate=True)
    h = shard(h, ("batch", "seq", "mlp"))
    y = h @ p["w_down"].astype(x.dtype)
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig, abstract=False):
    ks = jax.random.split(key, 2) if key is not None else [None, None]
    p = {"tokens": make_param(ks[0], (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              cfg.param_dtype, scale=0.02, abstract=abstract)}
    if not cfg.tie_embeddings:
        p["head"] = make_param(ks[1], (cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                               cfg.param_dtype, abstract=abstract)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["tokens"].astype(cfg.dtype), tokens, axis=0)
    if cfg.embedding_multiplier != 1.0:
        x = x * jnp.asarray(cfg.embedding_multiplier, dtype=x.dtype)
    return shard(x, ("batch", "seq", "embed"))


def lm_logits(p, x, cfg: ModelConfig):
    w = p["tokens"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype), preferred_element_type=jnp.float32)
    if cfg.logit_scale != 1.0:
        logits = logits * cfg.logit_scale
    logits = _softcap(logits, cfg.final_logit_softcap)
    return shard(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in fp32. logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def fused_cross_entropy(params_embed, x, labels, cfg, mask=None, chunk: int = 256,
                        unroll: bool = False):
    """Sequence-chunked CE: logits for a token chunk are computed, reduced to
    (logsumexp, gold-logit) partials, and *discarded* — the full (B, S, V)
    fp32 logits tensor never exists (§Perf iter 5: it dominated HBM bytes for
    every large-vocab train cell; command-r train_4k memory term 29.3s).

    Gold logits are extracted with a one-hot contraction so the vocab dim can
    stay ``model``-sharded (take_along_axis would force an all-gather)."""
    B, S, D = x.shape
    V = cfg.vocab_size
    w = params_embed["tokens"].T if cfg.tie_embeddings else params_embed["head"]
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None else jnp.ones((B, S), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    Sp = x.shape[1]
    nc = Sp // chunk

    def body(carry, i):
        nll_sum, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        ms = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", xs, w.astype(xs.dtype),
                            preferred_element_type=jnp.float32)
        if cfg.logit_scale != 1.0:
            logits = logits * cfg.logit_scale
        logits = _softcap(logits, cfg.final_logit_softcap)
        logits = shard(logits, ("batch", "seq", "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)  # (B, c)
        onehot = jax.nn.one_hot(ls, V, dtype=logits.dtype)
        onehot = shard(onehot, ("batch", "seq", "vocab"))
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll_sum = nll_sum + jnp.sum((logz - gold) * ms)
        cnt = cnt + jnp.sum(ms)
        return (nll_sum, cnt), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (nll_sum, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.float32)),
                                     jnp.arange(nc), unroll=unroll)
    return nll_sum / jnp.maximum(cnt, 1.0)
