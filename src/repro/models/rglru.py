"""RecurrentGemma / Griffin recurrent block: RG-LRU with conv1d + GeGLU gate.

The diagonal linear recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` (log-depth, fully materialised ops — the
TPU-idiomatic equivalent of Griffin's custom linear-scan kernel; also keeps
all FLOPs visible to HLO cost analysis).  Decode is a single-step update.

Cache layout per recurrent layer: (conv_state (B, W-1, lru), h (B, lru) fp32).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.parallel import make_param, shard

_C = 8.0  # RG-LRU decay sharpness constant (Griffin)


def init_rglru_block(key, cfg: ModelConfig, abstract=False):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7) if key is not None else [None] * 7
    return {
        # two input branches: recurrent branch + gate branch
        "w_rec_in": make_param(ks[0], (D, W), ("embed", "mlp"), cfg.param_dtype, abstract=abstract),
        "w_gate_in": make_param(ks[1], (D, W), ("embed", "mlp"), cfg.param_dtype, abstract=abstract),
        "conv_w": make_param(ks[2], (cfg.ssm_conv_width, W), ("conv", "mlp"), cfg.param_dtype,
                             scale=1.0 / math.sqrt(cfg.ssm_conv_width), abstract=abstract),
        "conv_b": make_param(ks[2], (W,), ("mlp",), cfg.param_dtype, init="zeros", abstract=abstract),
        # RG-LRU gates (per-channel diagonal)
        "w_a": make_param(ks[3], (W,), ("mlp",), "float32", init="zeros", abstract=abstract),
        "b_a": make_param(ks[3], (W,), ("mlp",), "float32", init="zeros", abstract=abstract),
        "w_x": make_param(ks[4], (W,), ("mlp",), "float32", init="ones", abstract=abstract),
        "b_x": make_param(ks[4], (W,), ("mlp",), "float32", init="zeros", abstract=abstract),
        "lambda_p": make_param(ks[5], (W,), ("mlp",), "float32", init="ones", abstract=abstract),
        "w_out": make_param(ks[6], (W, D), ("mlp", "embed"), cfg.param_dtype,
                            scale=0.02 / math.sqrt(2 * cfg.num_layers), abstract=abstract),
    }


def _rglru_coeffs(p, x):
    """Per-step gates. x: (B,S,W) (post-conv). Returns (a, b) fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["w_a"] + p["b_a"])  # recurrence gate
    i = jax.nn.sigmoid(xf * p["w_x"] + p["b_x"])  # input gate
    log_a = -_C * jax.nn.softplus(p["lambda_p"]) * r
    a = jnp.exp(log_a)
    gated_x = i * xf
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def _linear_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1 (seq)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru_block(p, u, cfg: ModelConfig, cache=None):
    """u: (B,S,D); cache: (conv_state, h) or None. Returns (out, new_cache)."""
    from repro.models.mamba2 import _causal_conv

    B, S, D = u.shape
    rec = u @ p["w_rec_in"].astype(u.dtype)  # (B,S,W)
    gate = jax.nn.gelu(u @ p["w_gate_in"].astype(u.dtype), approximate=True)

    conv_state = cache[0] if cache is not None else None
    rec, new_conv_state = _causal_conv(rec, p["conv_w"], p["conv_b"], conv_state)

    a, b = _rglru_coeffs(p, rec)
    if cache is not None and S == 1:
        h_prev = cache[1]
        h = a[:, 0] * h_prev + b[:, 0]
        y = h[:, None]
        new_h = h
    else:
        h0 = cache[1] if cache is not None else None
        y = _linear_scan(a, b, h0)
        new_h = y[:, -1]

    y = shard(y.astype(u.dtype), ("batch", "seq", "mlp"))
    out = (y * gate) @ p["w_out"].astype(u.dtype)
    new_cache = (new_conv_state, new_h) if cache is not None else None
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    conv_state = jnp.zeros((batch, cfg.ssm_conv_width - 1, cfg.lru_width), dtype)
    h = jnp.zeros((batch, cfg.lru_width), jnp.float32)
    return conv_state, h
