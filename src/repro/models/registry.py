"""Model registry: build/init/apply entry points + analytic parameter counts."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import transformer as T


class Model(NamedTuple):
    """Bound model API (params passed explicitly — pure functions)."""

    cfg: ModelConfig
    init: Callable  # (key=None, abstract=False) -> (params, axes)
    loss: Callable  # (params, batch) -> (loss, metrics)
    forward: Callable  # (params, batch) -> (logits, aux)
    prefill: Callable  # (params, batch, cache) -> (logits, cache)
    decode_step: Callable  # (params, tokens, cache, pos) -> (logits, cache)
    init_cache: Callable  # (batch, max_seq) -> cache


def build_model(cfg: ModelConfig, remat: bool = False) -> Model:
    def init(key=None, abstract=False):
        return T.init_params(cfg, key=key, abstract=abstract)

    def loss(params, batch):
        return T.loss_fn(params, batch, cfg, remat=remat)

    def forward(params, batch):
        return T.forward(params, batch, cfg, remat=remat)

    def prefill_fn(params, batch, cache):
        return T.prefill(params, batch, cfg, cache)

    def decode_fn(params, tokens, cache, pos, enc_out=None):
        return T.decode_step(params, tokens, cache, pos, cfg, enc_out=enc_out)

    def cache_fn(batch_size, max_seq, dtype=None):
        return T.init_cache(cfg, batch_size, max_seq, dtype=dtype)

    return Model(cfg, init, loss, forward, prefill_fn, decode_fn, cache_fn)


# ---------------------------------------------------------------------------
# Analytic parameter counts (used by the paper's delay model + roofline)
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    D, H, Kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = D * H * hd + 2 * D * Kv * hd + H * hd * D
    if cfg.use_bias:
        n += H * hd + 2 * Kv * hd + D
    if cfg.qk_norm:
        n += 2 * hd
    return n


def _mlp_params(cfg: ModelConfig) -> int:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation in ("swiglu", "geglu"):
        return 3 * D * F
    n = 2 * D * F
    if cfg.use_bias:
        n += F + D
    return n


def _moe_params(cfg: ModelConfig) -> int:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return D * E + 3 * E * D * F


def _mamba_params(cfg: ModelConfig) -> int:
    from repro.models.mamba2 import dims

    d_inner, H, P, N, conv_ch = dims(cfg)
    D = cfg.d_model
    in_dim = 2 * d_inner + 2 * N + H
    return (D * in_dim + cfg.ssm_conv_width * conv_ch + conv_ch
            + 3 * H + d_inner + d_inner * D)


def _rglru_params(cfg: ModelConfig) -> int:
    D, W = cfg.d_model, cfg.lru_width
    return 2 * D * W + cfg.ssm_conv_width * W + W + 5 * W + W * D


def _sublayer_params(cfg: ModelConfig, ch: str, cross: bool = False) -> int:
    D = cfg.d_model
    norm = D
    if ch in ("G", "L"):
        n = norm + _attn_params(cfg)
        if cross:
            n += norm + _attn_params(cfg)
        if not cfg.parallel_block:
            n += norm
        if cfg.use_post_norm:
            n += 2 * norm
        n += _moe_params(cfg) if cfg.num_experts else _mlp_params(cfg)
        return n
    if ch == "M":
        return norm + _mamba_params(cfg)
    if ch == "R":
        return norm + _rglru_params(cfg) + norm + _mlp_params(cfg)
    raise ValueError(ch)


def count_params(cfg: ModelConfig, trainable_only: bool = False) -> int:
    """Total parameter count; with trainable_only, LoRA adapter params only."""
    if trainable_only:
        from repro.core.lora import lora_param_count

        return lora_param_count(cfg)
    D = cfg.d_model
    n = cfg.vocab_size * D  # embed
    if not cfg.tie_embeddings:
        n += D * cfg.vocab_size
    cross = cfg.family == "encdec"
    for ch in cfg.pattern:
        n += _sublayer_params(cfg, ch, cross=cross)
    n += D  # final norm
    if cfg.family == "encdec":
        for _ in range(cfg.num_encoder_layers):
            n += _sublayer_params(cfg, "G", cross=False)
        n += D + cfg.encoder_seq * D + 32768 * D
    if cfg.family == "vlm":
        n += 1024 * D + D + D * D + D
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
    if not cfg.num_experts:
        return count_params(cfg)
    act = cfg.replace(num_experts=cfg.num_experts_per_tok)
    # router counted fully; experts scaled to top-k
    return count_params(act)
