"""Federated data partitioners (IID and Dirichlet non-IID)."""

from __future__ import annotations

import numpy as np


def iid_partition(n: int, num_clients: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(idx, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int, alpha: float = 0.5,
                        seed: int = 0, min_size: int = 2) -> list[np.ndarray]:
    """Label-skewed non-IID split: per-class Dirichlet(α) proportions."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    for _ in range(100):
        buckets: list[list[int]] = [[] for _ in range(num_clients)]
        for c in classes:
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * num_clients)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        sizes = [len(b) for b in buckets]
        if min(sizes) >= min_size:
            return [np.sort(np.array(b, dtype=np.int64)) for b in buckets]
    raise ValueError(
        f"dirichlet_partition could not give every one of {num_clients} "
        f"clients >= {min_size} samples in 100 draws (alpha={alpha}, "
        f"n={len(labels)}; last draw's sizes: {sizes}) — lower min_size, "
        f"raise alpha, or provide more samples")
