from repro.data.tokens import TokenStream, synthetic_lm_batch
from repro.data.blog_feedback import BlogFeedback
from repro.data.partition import dirichlet_partition, iid_partition

__all__ = [
    "TokenStream",
    "synthetic_lm_batch",
    "BlogFeedback",
    "dirichlet_partition",
    "iid_partition",
]
