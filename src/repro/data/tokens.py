"""Deterministic synthetic LM token pipeline.

Offline container ⇒ no real corpora; we generate a *learnable* synthetic
stream (a Markov-ish mixture over the vocabulary) so train-loss decreases
measurably in examples/tests, deterministically seeded, shardable by
(host, step) with no cross-host coordination — the same recipe production
pipelines use for data-parallel determinism (index-based, stateless)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(key, batch: int, seq: int, vocab: int, structure: float = 0.8):
    """Structured random tokens: x_{t+1} depends on x_t (learnable bigram)."""
    k1, k2, k3 = jax.random.split(key, 3)
    # fixed random bigram table (function of vocab only — learnable signal)
    perm = jax.random.permutation(jax.random.PRNGKey(1234), vocab)
    first = jax.random.randint(k1, (batch, 1), 0, vocab)

    def step(tok, k):
        nxt_det = perm[tok]
        rnd = jax.random.randint(k, tok.shape, 0, vocab)
        use_det = jax.random.bernoulli(k, structure, tok.shape)
        return jnp.where(use_det, nxt_det, rnd)

    ks = jax.random.split(k2, seq)
    toks = [first[:, 0]]
    for i in range(seq - 1):
        toks.append(step(toks[-1], ks[i]))
    tokens = jnp.stack(toks, axis=1)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return {"tokens": tokens, "labels": labels, "mask": jnp.ones((batch, seq), jnp.float32)}


@dataclass
class TokenStream:
    """Stateless, index-addressable batch source (resume = remember step)."""

    batch: int
    seq: int
    vocab: int
    seed: int = 0
    structure: float = 0.8

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return synthetic_lm_batch(key, self.batch, self.seq, self.vocab, self.structure)

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def client_batches(stream: TokenStream, step: int, num_clients: int):
    """Stacked (K, B, S) batches — one slice per federated client."""
    batches = [stream.batch_at(step * num_clients + k) for k in range(num_clients)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *batches)
