"""Fault-tolerant pytree checkpointing.

Production behaviours implemented here:
  * atomic writes (tmp dir + os.replace) — a crash mid-save never corrupts
    the latest checkpoint;
  * step-tagged directories + retention policy;
  * corrupted-checkpoint quarantine on restore (falls back to the previous
    valid step);
  * **elastic restore**: arrays are saved host-side (numpy) with their tree
    structure; on load they are placed onto *whatever mesh/sharding the new
    job provides* — restarting on a different pod count reshards transparently;
  * resume metadata (step, data-stream position, RNG key, fedsllm round).
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import tempfile
import time
from typing import Any, Optional

import jax
import numpy as np


def _json_safe(obj):
    """Metadata often carries numpy scalars (simulated times, round indices);
    coerce them so ``json.dump`` never rejects a checkpoint save."""
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"metadata value of type {type(obj).__name__} "
                    f"is not JSON-serialisable")


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        """Atomic save: write to tmp, fsync, rename into place."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=self.directory)
        try:
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(host_leaves)})
            with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                pickle.dump(treedef, f)
            meta = dict(metadata or {})
            meta.update({"step": step, "time": time.time(), "n_leaves": len(host_leaves)})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f, default=_json_safe)
            # commit marker makes partially-written dirs detectable
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, shardings: Any = None
                ) -> tuple[Any, dict]:
        """Restore (tree, metadata). Quarantines corrupt dirs and falls back.

        shardings: optional pytree of jax.sharding.Sharding — elastic
        restore places each leaf with jax.device_put onto the new mesh."""
        candidates = self.steps() if step is None else [step]
        for s in reversed(candidates):
            d = self._step_dir(s)
            try:
                with open(os.path.join(d, "treedef.pkl"), "rb") as f:
                    treedef = pickle.load(f)
                data = np.load(os.path.join(d, "arrays.npz"))
                leaves = [data[f"a{i}"] for i in range(len(data.files))]
                with open(os.path.join(d, "meta.json")) as f:
                    meta = json.load(f)
                tree = jax.tree_util.tree_unflatten(treedef, leaves)
                if shardings is not None:
                    tree = jax.tree.map(lambda x, sh: jax.device_put(x, sh), tree, shardings)
                return tree, meta
            except Exception:
                quarantine = d + ".corrupt"
                try:
                    os.replace(d, quarantine)
                except OSError:
                    pass
                continue
        raise FileNotFoundError(f"no restorable checkpoint in {self.directory}")

    def restore_or_none(self, shardings: Any = None):
        try:
            return self.restore(shardings=shardings)
        except FileNotFoundError:
            return None
