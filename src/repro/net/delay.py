"""Per-hop delay composition for hierarchical topologies.

Extends :class:`repro.core.fedsllm.RoundTiming` — the §III per-client round
time (compute + fed uplink + per-iteration main uplink) — with the backhaul
hop a multi-hop graph adds: each client's end-to-end round time is the
critical path through its own route,

    total_k = compute_k + t_c,k + V·t_s,k + backhaul_{edge(k)}

and the round's wall-clock stays the max over clients of that per-path
total, so deadline straggler masks and the campaign's simulated clock work
unchanged on the richer timing object.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.fedsllm import RoundTiming


@dataclass
class HierRoundTiming(RoundTiming):
    """RoundTiming plus the backhaul hop of each client's path.

    ``total`` already includes ``backhaul`` (critical-path composed); the
    extra fields keep the per-hop breakdown inspectable for reporting.
    """

    backhaul: np.ndarray = None  # (K,) backhaul seconds on each client's path
    edge_of: Optional[np.ndarray] = None  # (K,) edge index per client


def compose(wireless: RoundTiming, backhaul_k: np.ndarray,
            assign: Optional[np.ndarray]) -> HierRoundTiming:
    """Compose the wireless hop with a per-client backhaul hop.

    ``backhaul_k`` is already expanded to (K,) — each client carries the
    backhaul time of the edge it is attached to (all of a cell's traffic
    shares the pipe, so every member waits for the full cell transfer).
    """
    backhaul_k = np.asarray(backhaul_k, float)
    return HierRoundTiming(
        compute=wireless.compute,
        uplink_fed=wireless.uplink_fed,
        uplink_main=wireless.uplink_main,
        total=wireless.total + backhaul_k,
        backhaul=backhaul_k,
        edge_of=None if assign is None else np.asarray(assign),
    )
