"""Per-hop delay composition for hierarchical topologies.

Extends :class:`repro.core.fedsllm.RoundTiming` — the §III per-client round
time (compute + fed uplink + per-iteration main uplink) — with the backhaul
hop a multi-hop graph adds: each client's end-to-end round time is the
critical path through its own route,

    total_k = compute_k + t_c,k + V·t_s,k + backhaul_{edge(k)}

and the round's wall-clock stays the max over clients of that per-path
total, so deadline straggler masks and the campaign's simulated clock work
unchanged on the richer timing object.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.fedsllm import RoundTiming


@dataclass
class HierRoundTiming(RoundTiming):
    """RoundTiming plus the backhaul/downlink hops of each client's path.

    ``total`` already includes ``backhaul`` (and ``downlink`` when the
    broadcast term is enabled) — critical-path composed; the extra fields
    keep the per-hop breakdown inspectable for reporting.
    """

    backhaul: np.ndarray = None  # (K,) backhaul seconds on each client's path
    edge_of: Optional[np.ndarray] = None  # (K,) edge index per client
    downlink: Optional[np.ndarray] = None  # (K,) broadcast seconds (or None)


def compose(wireless: RoundTiming, backhaul_k: np.ndarray,
            assign: Optional[np.ndarray],
            downlink_k: Optional[np.ndarray] = None) -> HierRoundTiming:
    """Compose the wireless hop with per-client backhaul/downlink hops.

    ``backhaul_k`` is already expanded to (K,) — each client carries the
    backhaul time of the edge it is attached to.  Under the legacy serial
    pipe all of a cell's traffic shares it, so every member waits for the
    full cell transfer; under the queueing models
    (``HierTopology(backhaul_model="fifo" | "ps")``) it is each client's
    own wait+service in the SHARED metro queue.  ``downlink_k`` (optional)
    adds the per-round global-model broadcast cost — one multicast
    transmission per cell, every member pays the same wait
    (``repro.des.queueing.broadcast_seconds``).
    """
    backhaul_k = np.asarray(backhaul_k, float)
    total = wireless.total + backhaul_k
    if downlink_k is not None:
        downlink_k = np.asarray(downlink_k, float)
        total = total + downlink_k
    return HierRoundTiming(
        compute=wireless.compute,
        uplink_fed=wireless.uplink_fed,
        uplink_main=wireless.uplink_main,
        total=total,
        backhaul=backhaul_k,
        edge_of=None if assign is None else np.asarray(assign),
        downlink=downlink_k,
    )
