"""Topology-aware resource allocation: problems (16)/(17) per edge cell.

At a fixed η the paper's convex problem (17) decomposes over a hierarchical
graph: each edge owns an independent copy of the bandwidth pool (spatial
reuse — cells don't interfere in the FDMA model), so each cell is exactly
the flat problem restricted to its own clients and is solved by the
**existing** Lemma-3 machinery (``core.resource_alloc``).  What does NOT
decompose is the η sweep: Lemma 1/2's global-round and local-iteration
schedule is shared by every client, and the objective is the hierarchical
critical path

    T(η) = I0(η) · max_k ( τ_k(η) + t_c,k + V(η)·t_s,k + backhaul_{edge(k)}(η) )

(backhaul included — for ``relay`` it even depends on η through V).  So the
sweep lives at the topology level: for each candidate η, solve every cell
independently at that η, scatter the per-cell solutions back into (K,)
arrays, price the combined allocation under the hierarchical timing, and
keep the best.  ``eta_search`` modes ('grid' / 'coarse' / 'warm') reuse the
same grids as the flat ``optimize`` (``eta_grid_for``), so the campaign's
warm per-round re-solve works identically on every topology.

Under a QUEUED backhaul (``backhaul_model="fifo" | "ps"``) the edge→cloud
leg is a shared metro queue and the backhaul term above becomes each
client's own wait + service in that queue — a function of every cell's
arrival pattern, which the per-cell convex solves themselves determine.
The 'proposed' strategy therefore closes the allocator↔queueing loop with
a damped fixed point at each candidate η (:func:`solve_wait_aware`): solve
the cells with a per-client *expected-wait* term ``w_k`` folded into their
latency budgets (``R_k = T/I0 − τ_k − w_k``), re-derive ``w`` from the
candidate's own wireless completion times via the analytic
``queueing.md1_mean_wait`` (FIFO) / ``queueing.ps_mean_wait`` (PS) models,
and iterate to a fixed point under a deterministic iteration cap.  Every
iterate — including the wait-blind first one — is priced through the TRUE
queued ``topology.round_timing`` and the best survives, so the wait-aware
solution is never worse than the wait-blind one at any η.  With
``backhaul_model="serial"`` none of this runs and the solve is
bit-identical to the legacy allocator.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import fedsllm
from repro.core import resource_alloc as ra
from repro.core.resource_alloc import Allocation
from repro.des import queueing


def subnetwork(net: dm.Network, idx: np.ndarray) -> dm.Network:
    """The network restricted to clients ``idx``, keeping the full bandwidth
    pools (each cell owns an independent copy — spatial reuse)."""
    take = lambda a: None if a is None else np.asarray(a)[idx]  # noqa: E731
    return dataclasses.replace(
        net, g_c=take(net.g_c), g_s=take(net.g_s), C_k=take(net.C_k),
        D_k=take(net.D_k), f_max=take(net.f_max), p_c_max=take(net.p_c_max),
        p_s_max=take(net.p_s_max), xy=take(net.xy), pl_db=take(net.pl_db))


def _infeasible(fcfg: FedsLLMConfig, strategy: str) -> Allocation:
    """The nothing-worked sentinel: ``T=+inf``, ``eta=nan``.

    η is NaN on purpose — an infeasible round has no solved η*, and a
    fabricated finite value could silently be adopted as a training η by a
    reallocating campaign (``Experiment.set_eta`` and the round-state guard
    both reject non-finite η with a loud error instead)."""
    return Allocation(np.inf, np.nan, fcfg.split_ratio_min, None, None, None,
                      None, False, strategy)


def _transmit_time(bits: float, rate: np.ndarray) -> np.ndarray:
    """bits/rate with rate→0 treated as an outage (+inf, a sure straggler)."""
    rate = np.asarray(rate, float)
    out = np.full_like(rate, np.inf)
    np.divide(bits, rate, out=out, where=rate > 0)
    return out


def _broadcast_reps(fcfg: FedsLLMConfig, net: dm.Network, idx: np.ndarray,
                    rep_idx: np.ndarray, a: Allocation) -> Allocation:
    """Expand a representative-cell solve to the full cell.

    Each non-representative member adopts the bandwidth split of its nearest
    representative in client-side channel gain (the Lemma-3 split is
    monotone in gain, so the nearest-gain rep's share is the right
    multiplicity class), re-timed at the member's OWN gains — the combined
    allocation still prices every client's real link, only the convex solve
    was restricted."""
    g = np.asarray(net.g_c, float)
    order = np.argsort(g[rep_idx], kind="stable")
    rg = g[rep_idx][order]
    pos = np.searchsorted(rg, g[idx])
    lo = np.clip(pos - 1, 0, len(rg) - 1)
    hi = np.clip(pos, 0, len(rg) - 1)
    nearer = np.where(np.abs(g[idx] - rg[lo]) <= np.abs(rg[hi] - g[idx]),
                      lo, hi)
    src = order[nearer]
    b_c = np.asarray(a.b_c)[src]
    b_s = np.asarray(a.b_s)[src]
    r_c = dm.rate(b_c, g[idx], np.asarray(net.p_c_max)[idx], net.N0)
    r_s = dm.rate(b_s, np.asarray(net.g_s)[idx],
                  np.asarray(net.p_s_max)[idx], net.N0)
    return dataclasses.replace(a, b_c=b_c, b_s=b_s,
                               t_c=_transmit_time(fcfg.s_c_bits, r_c),
                               t_s=_transmit_time(fcfg.s_bits, r_s))


def _solve_cell(fcfg: FedsLLMConfig, net: dm.Network, idx: np.ndarray,
                allocate_fn, *, population=None,
                extra_delay: Optional[np.ndarray] = None,
                **cell_kw) -> tuple:
    """One cell's convex solve, population-aware: ``(idx, Allocation)``.

    Without a population holding ``rep_ids`` (exact/compact, or mean-field
    with reps ≥ K) this is exactly the legacy per-cell call — bit-identical.
    With representatives, the solve runs on the cell's reps only, with the
    cell's bandwidth pool scaled by the representative fraction so each rep
    stands in for its multiplicity share of the population (the per-client
    share of the pool is preserved in expectation); the solution is then
    broadcast back to every member via :func:`_broadcast_reps`.  Cells whose
    representatives don't cover them (no rep attached) fall back to the
    exact solve.
    """
    rep = getattr(population, "rep_ids", None)
    sub_idx = idx
    if rep is not None:
        rep_in = np.intersect1d(idx, rep)
        if 0 < len(rep_in) < len(idx):
            fcfg = dataclasses.replace(
                fcfg, bandwidth_total_hz=(fcfg.bandwidth_total_hz
                                          * len(rep_in) / len(idx)))
            sub_idx = rep_in
    if extra_delay is not None:
        cell_kw["extra_delay"] = np.asarray(extra_delay)[sub_idx]
    a = allocate_fn(fcfg, subnetwork(net, sub_idx), **cell_kw)
    if sub_idx is not idx and a.feasible and a.t_c is not None:
        a = _broadcast_reps(fcfg, net, idx, sub_idx, a)
    return idx, a


def _combine(fcfg: FedsLLMConfig, net: dm.Network, assign: np.ndarray,
             topology, solved: list, eta: float,
             strategy: str, population=None) -> Optional[Allocation]:
    """Scatter per-cell solutions into (K,) arrays and price the combined
    allocation under the hierarchical critical path.  None if any cell was
    infeasible at this η.

    The critical path maxes over FINITE clients only: an outage'd client
    (+inf end-to-end total) is exactly the one the campaign's deadline mask
    drops, and letting it poison every η candidate with ``T=+inf`` would
    degenerate the sweep into silently keeping the first grid point.  +inf
    is returned only when NO client is finite."""
    K = net.K
    t_c, t_s = np.zeros(K), np.zeros(K)
    b_c, b_s = np.zeros(K), np.zeros(K)
    for idx, a in solved:
        if not a.feasible or a.t_c is None:
            return None
        t_c[idx], t_s[idx] = a.t_c, a.t_s
        b_c[idx], b_s[idx] = a.b_c, a.b_s
    alloc = Allocation(np.inf, eta, fcfg.split_ratio_min, t_c, t_s, b_c, b_s,
                       True, strategy)
    timing = topology.round_timing(fcfg, net, alloc, eta, assign,
                                   population=population)
    total = np.asarray(timing.total, float)
    finite = total[np.isfinite(total)]
    worst = float(np.max(finite)) if finite.size else np.inf
    T = dm.global_rounds(fcfg, eta) * worst
    return dataclasses.replace(alloc, T=T)


def cell_latency(fcfg: FedsLLMConfig, net: dm.Network, alloc: Allocation,
                 assign: np.ndarray, topology, eta: float) -> np.ndarray:
    """(M,) total training latency of each cell under ``alloc`` — the
    per-cell version of the paper's T (empty cells are NaN).  The per-cell
    comparison of the proposed allocator vs the BA baseline reports this."""
    timing = topology.round_timing(fcfg, net, alloc, eta, assign)
    I0 = dm.global_rounds(fcfg, eta)
    out = np.full(topology.num_edges, np.nan)
    for m in range(topology.num_edges):
        members = np.asarray(assign) == m
        if np.any(members):
            out[m] = I0 * float(np.max(np.asarray(timing.total)[members]))
    return out


# ---------------------------------------------------------------------------
# Wait-aware allocation: close the allocator↔queueing loop (fifo / ps)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WaitInfo:
    """Diagnostics of one :func:`solve_wait_aware` fixed point (one η)."""

    eta: float
    iters: int
    converged: bool
    max_delta: float


def expected_backhaul_hop(fcfg: FedsLLMConfig, net: dm.Network,
                          assign: np.ndarray, topology, eta: float,
                          wireless_total: np.ndarray) -> np.ndarray:
    """(K,) analytic *expected* backhaul hop (queueing wait + own service)
    per client under the shared metro queue, for a candidate allocation's
    wireless completion times.

    Each cell's contribution to the queue's load is derived from the
    candidate itself: its jobs (``topology._backhaul_jobs`` — per client for
    edge-cloud/relay, one pre-aggregated delta per edge for edge-agg) arrive
    over the window spanned by the wireless completions, giving the shared
    queue an aggregate arrival rate λ = Σ_m n_m / span.  The mean wait is
    the validated analytic model — M/D/1 (``md1_mean_wait``) for FIFO,
    M/D/1-PS (``ps_mean_wait``) for PS — capped at the all-at-once batch
    backlog ``(n−1)·s̄/2`` so a saturated window (ρ ≥ 1 over the span)
    prices the bounded per-round burst rather than a steady-state +inf.
    The mean is distributed over the jobs as a linear ramp in arrival rank
    (later arrivals expect proportionally more backlog), which is what lets
    the per-cell solves *stagger* completions instead of bursting the queue
    with a simultaneous batch.  Clients whose wireless total is non-finite
    never reach the queue and get hop 0 (matching ``_queued_backhaul``).
    """
    totals = np.asarray(wireless_total, float)
    arrivals, bits, job_of = topology._backhaul_jobs(fcfg, assign, eta,
                                                     totals)
    service = queueing.service_seconds(bits, topology.backhaul_bps)
    finite = np.isfinite(arrivals)
    n = int(np.count_nonzero(finite))
    hop_jobs = np.zeros(len(arrivals))
    if n:
        s_bar = float(np.mean(service[finite]))
        if n > 1 and s_bar > 0:
            span = float(np.max(arrivals[finite]) - np.min(arrivals[finite]))
            lam = n / span if span > 0 else np.inf
            mean_wait = (queueing.ps_mean_wait(lam, s_bar)
                         if topology.backhaul_model == "ps"
                         else queueing.md1_mean_wait(lam, s_bar))
            mean_wait = min(mean_wait, 0.5 * (n - 1) * s_bar)
            ranks = np.empty(n)
            ranks[np.argsort(arrivals[finite], kind="stable")] = np.arange(n)
            wait = mean_wait * 2.0 * ranks / (n - 1)
            hop_jobs[finite] = wait + service[finite]
        else:
            hop_jobs[finite] = service[finite]
    hop = hop_jobs[job_of]
    hop[~np.isfinite(totals)] = 0.0
    return hop


def solve_wait_aware(fcfg: FedsLLMConfig, net: dm.Network,
                     assign: np.ndarray, topology, allocate_fn, eta: float, *,
                     strategy: str = "proposed", model_params=None,
                     population=None,
                     **kw) -> tuple[Optional[Allocation], WaitInfo]:
    """The damped allocation↔wait fixed point at one fixed η.

    Iterate: solve every cell with the current per-client expected-wait
    term ``w`` folded into its latency budget (``extra_delay`` of the
    Lemma-3 solver), re-derive ``w`` from the candidate's wireless
    completion times (:func:`expected_backhaul_hop`), damp
    (``w ← (1−γ)·w + γ·w_new``, γ = ``topology.wait_damping``) and repeat
    under the deterministic cap ``topology.wait_iters``.  Iterate 0 runs
    with no wait term — the exact wait-blind solve — and every iterate is
    priced through the true queued ``round_timing`` (``_combine``), with
    the best kept: the result can only improve on the wait-blind
    allocation.

    Convergence is declared on the OBJECTIVE, not the raw wait vector: the
    loop stops (a) immediately after the blind iterate when the expected
    hop is negligible against the round's critical path (an uncontended
    queue can't move the optimum beyond the solver's own tolerance — this
    keeps default-capacity graphs at one extra hop evaluation), or (b) when
    an iterate fails to improve the incumbent's true-priced T by more than
    0.01% (the rank-based wait map can cycle between equivalent staggerings
    under heavy contention, but the allocations it produces stop improving
    — that plateau IS the fixed point of the objective).

    Returns ``(best_candidate_or_None, WaitInfo)``; pure in its arguments
    (no RNG, numpy-deterministic), so campaigns that re-solve per round
    stay pure functions of ``(RunConfig, seed)``.
    """
    cells = [np.where(np.asarray(assign) == m)[0]
             for m in range(topology.num_edges)]
    cells = [idx for idx in cells if len(idx)]
    eta = float(eta)

    def solve(extra: Optional[np.ndarray]) -> Optional[Allocation]:
        solved = [_solve_cell(fcfg, net, idx, allocate_fn,
                              population=population, extra_delay=extra,
                              model_params=model_params,
                              eta_grid=np.array([eta]), **kw)
                  for idx in cells]
        return _combine(fcfg, net, assign, topology, solved, eta, strategy,
                        population=population)

    cap = int(getattr(topology, "wait_iters", 8))
    damping = float(getattr(topology, "wait_damping", 0.5))
    rtol = 1e-4  # matches the exact solver's own bisection tolerance scale
    w = np.zeros(net.K)
    best: Optional[Allocation] = None
    info = WaitInfo(eta=eta, iters=0, converged=False, max_delta=np.inf)
    for it in range(cap):
        cand = solve(None if it == 0 else w)
        info.iters = it + 1
        if cand is None:
            # a cell went infeasible under the current wait estimate; the
            # best earlier iterate stands (None only if η itself infeasible)
            break
        if best is not None and not cand.T < best.T * (1.0 - rtol):
            # the loop stopped producing better allocations — the
            # objective's fixed point (see the docstring)
            if cand.T < best.T:
                best = cand
            info.converged = True
            break
        best = cand if best is None or cand.T < best.T else best
        wireless = np.asarray(
            fedsllm.simulate_round_time(fcfg, net, cand, eta).total, float)
        w_new = expected_backhaul_hop(fcfg, net, assign, topology, eta,
                                      wireless)
        info.max_delta = float(np.max(np.abs(w_new - w)))
        finite = wireless[np.isfinite(wireless)]
        round_scale = float(np.max(finite)) if finite.size else 0.0
        if float(np.max(w_new)) <= rtol * round_scale:
            # uncontended queue: the whole hop is below the solver's
            # tolerance on the critical path — the blind solve stands
            info.converged = True
            break
        w = (1.0 - damping) * w + damping * w_new
    return best, info


def optimize_cells(fcfg: FedsLLMConfig, net: dm.Network,
                   assign: np.ndarray, topology, allocate_fn, *,
                   strategy: str = "proposed", model_params=None,
                   eta_search: str = "grid", eta0: Optional[float] = None,
                   population=None,
                   **kw) -> Allocation:
    """Per-edge-cell (16)/(17): topology-level η sweep, independent convex
    cell subproblems at each fixed η (see the module docstring).

    ``allocate_fn`` is the experiment's registered allocator strategy —
    called per cell with a single-η grid, so every strategy branch
    ('proposed' exact solver, 'EB' closed form, …) works per cell unchanged.
    'BA'/'FE' pin η = 0.1 themselves, so they need no sweep at all.

    Under a queued backhaul (``topology.backhaul_model`` 'fifo'/'ps' with
    ``topology.wait_aware`` true) the 'proposed' strategy solves each η via
    the wait-aware fixed point (:func:`solve_wait_aware`); per-η
    :class:`WaitInfo` diagnostics land on ``topology.wait_diag``.  The
    EB/FE/BA baselines stay wait-blind by design (their sweep still prices
    the true queue through ``round_timing``), and ``"serial"`` keeps the
    legacy path bit-identical.
    """
    cells = [np.where(np.asarray(assign) == m)[0]
             for m in range(topology.num_edges)]
    cells = [idx for idx in cells if len(idx)]

    if strategy in ("BA", "FE"):  # fixed η = 0.1, one solve per cell
        solved = [_solve_cell(fcfg, net, idx, allocate_fn,
                              population=population,
                              model_params=model_params, **kw)
                  for idx in cells]
        combined = _combine(fcfg, net, assign, topology, solved, 0.1,
                            strategy, population=population)
        return combined if combined is not None else _infeasible(fcfg, strategy)

    wait_aware = (strategy == "proposed"
                  and getattr(topology, "backhaul_model", "serial") != "serial"
                  and getattr(topology, "wait_aware", True))
    if wait_aware:
        topology.wait_diag = []

    def solve_at(eta: float) -> Optional[Allocation]:
        if wait_aware:
            cand, diag = solve_wait_aware(fcfg, net, assign, topology,
                                          allocate_fn, eta, strategy=strategy,
                                          model_params=model_params,
                                          population=population, **kw)
            topology.wait_diag.append(diag)
            return cand
        solved = [_solve_cell(fcfg, net, idx, allocate_fn,
                              population=population,
                              model_params=model_params,
                              eta_grid=np.array([eta]), **kw)
                  for idx in cells]
        return _combine(fcfg, net, assign, topology, solved, eta, strategy,
                        population=population)

    best = None
    for eta in ra.eta_grid_for(fcfg, eta_search, eta0):
        cand = solve_at(float(eta))
        if cand is not None and (best is None or cand.T < best.T):
            best = cand
    if eta_search == "coarse" and best is not None:
        # the same local eta_step refinement the flat optimiser applies
        for eta in ra.eta_refine_grid(fcfg, best.eta):
            cand = solve_at(float(eta))
            if cand is not None and cand.T < best.T:
                best = cand
    return best if best is not None else _infeasible(fcfg, strategy)
