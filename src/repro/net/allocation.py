"""Topology-aware resource allocation: problems (16)/(17) per edge cell.

At a fixed η the paper's convex problem (17) decomposes over a hierarchical
graph: each edge owns an independent copy of the bandwidth pool (spatial
reuse — cells don't interfere in the FDMA model), so each cell is exactly
the flat problem restricted to its own clients and is solved by the
**existing** Lemma-3 machinery (``core.resource_alloc``) untouched.  What
does NOT decompose is the η sweep: Lemma 1/2's global-round and
local-iteration schedule is shared by every client, and the objective is
the hierarchical critical path

    T(η) = I0(η) · max_k ( τ_k(η) + t_c,k + V(η)·t_s,k + backhaul_{edge(k)}(η) )

(backhaul included — for ``relay`` it even depends on η through V).  So the
sweep lives at the topology level: for each candidate η, solve every cell
independently at that η, scatter the per-cell solutions back into (K,)
arrays, price the combined allocation under the hierarchical timing, and
keep the best.  ``eta_search`` modes ('grid' / 'coarse' / 'warm') reuse the
same grids as the flat ``optimize`` (``eta_grid_for``), so the campaign's
warm per-round re-solve works identically on every topology.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import resource_alloc as ra
from repro.core.resource_alloc import Allocation


def subnetwork(net: dm.Network, idx: np.ndarray) -> dm.Network:
    """The network restricted to clients ``idx``, keeping the full bandwidth
    pools (each cell owns an independent copy — spatial reuse)."""
    take = lambda a: None if a is None else np.asarray(a)[idx]  # noqa: E731
    return dataclasses.replace(
        net, g_c=take(net.g_c), g_s=take(net.g_s), C_k=take(net.C_k),
        D_k=take(net.D_k), f_max=take(net.f_max), p_c_max=take(net.p_c_max),
        p_s_max=take(net.p_s_max), xy=take(net.xy), pl_db=take(net.pl_db))


def _infeasible(fcfg: FedsLLMConfig, strategy: str) -> Allocation:
    return Allocation(np.inf, 0.1, fcfg.split_ratio_min, None, None, None,
                      None, False, strategy)


def _combine(fcfg: FedsLLMConfig, net: dm.Network, assign: np.ndarray,
             topology, solved: list, eta: float,
             strategy: str) -> Optional[Allocation]:
    """Scatter per-cell solutions into (K,) arrays and price the combined
    allocation under the hierarchical critical path.  None if any cell was
    infeasible at this η."""
    K = net.K
    t_c, t_s = np.zeros(K), np.zeros(K)
    b_c, b_s = np.zeros(K), np.zeros(K)
    for idx, a in solved:
        if not a.feasible or a.t_c is None:
            return None
        t_c[idx], t_s[idx] = a.t_c, a.t_s
        b_c[idx], b_s[idx] = a.b_c, a.b_s
    alloc = Allocation(np.inf, eta, fcfg.split_ratio_min, t_c, t_s, b_c, b_s,
                       True, strategy)
    timing = topology.round_timing(fcfg, net, alloc, eta, assign)
    T = dm.global_rounds(fcfg, eta) * float(np.max(timing.total))
    return dataclasses.replace(alloc, T=T)


def cell_latency(fcfg: FedsLLMConfig, net: dm.Network, alloc: Allocation,
                 assign: np.ndarray, topology, eta: float) -> np.ndarray:
    """(M,) total training latency of each cell under ``alloc`` — the
    per-cell version of the paper's T (empty cells are NaN).  The per-cell
    comparison of the proposed allocator vs the BA baseline reports this."""
    timing = topology.round_timing(fcfg, net, alloc, eta, assign)
    I0 = dm.global_rounds(fcfg, eta)
    out = np.full(topology.num_edges, np.nan)
    for m in range(topology.num_edges):
        members = np.asarray(assign) == m
        if np.any(members):
            out[m] = I0 * float(np.max(np.asarray(timing.total)[members]))
    return out


def optimize_cells(fcfg: FedsLLMConfig, net: dm.Network,
                   assign: np.ndarray, topology, allocate_fn, *,
                   strategy: str = "proposed", model_params=None,
                   eta_search: str = "grid", eta0: Optional[float] = None,
                   **kw) -> Allocation:
    """Per-edge-cell (16)/(17): topology-level η sweep, independent convex
    cell subproblems at each fixed η (see the module docstring).

    ``allocate_fn`` is the experiment's registered allocator strategy —
    called per cell with a single-η grid, so every strategy branch
    ('proposed' exact solver, 'EB' closed form, …) works per cell unchanged.
    'BA'/'FE' pin η = 0.1 themselves, so they need no sweep at all.
    """
    cells = [np.where(np.asarray(assign) == m)[0]
             for m in range(topology.num_edges)]
    cells = [idx for idx in cells if len(idx)]

    if strategy in ("BA", "FE"):  # fixed η = 0.1, one solve per cell
        solved = [(idx, allocate_fn(fcfg, subnetwork(net, idx),
                                    model_params=model_params, **kw))
                  for idx in cells]
        combined = _combine(fcfg, net, assign, topology, solved, 0.1, strategy)
        return combined if combined is not None else _infeasible(fcfg, strategy)

    def solve_at(eta: float) -> Optional[Allocation]:
        solved = [(idx, allocate_fn(fcfg, subnetwork(net, idx),
                                    model_params=model_params,
                                    eta_grid=np.array([eta]), **kw))
                  for idx in cells]
        return _combine(fcfg, net, assign, topology, solved, eta, strategy)

    best = None
    for eta in ra.eta_grid_for(fcfg, eta_search, eta0):
        cand = solve_at(float(eta))
        if cand is not None and (best is None or cand.T < best.T):
            best = cand
    if eta_search == "coarse" and best is not None:
        # the same local eta_step refinement the flat optimiser applies
        for eta in ra.eta_refine_grid(fcfg, best.eta):
            cand = solve_at(float(eta))
            if cand is not None and cand.T < best.T:
                best = cand
    return best if best is not None else _infeasible(fcfg, strategy)
