"""Hierarchical network topologies (multi-hop client→edge→cloud graphs).

``topology`` defines the :class:`Topology` protocol and the name registry —
``star`` (the flat FedsLLM default) | ``edge-cloud`` | ``edge-agg`` |
``relay`` — the 5th pluggable strategy axis of ``repro.api.Experiment``;
``delay`` composes per-hop times into an end-to-end critical-path
``RoundTiming``; ``allocation`` solves the paper's (16)/(17) per edge cell
(independent convex subproblems at fixed η, topology-level η sweep).
"""

# allocation/delay first: topology imports them from this package, so they
# must already be bound when a caller lands on repro.net.topology directly
from repro.net import allocation, delay
from repro.net.delay import HierRoundTiming
from repro.net.topology import (Topology, get_topology, topologies)

__all__ = ["Topology", "get_topology", "topologies", "HierRoundTiming",
           "allocation", "delay"]
