"""Hierarchical network topologies — the 5th pluggable strategy axis.

The paper's §IV system model is a *star*: every client has a direct wireless
link to one main server and one federated server sharing a single bandwidth
pool.  Follow-on deployments (SplitLLM's hierarchical split over wireless,
arXiv 2501.13318; edge-assisted SFL, arXiv 2504.14667) are *multi-hop*:
clients reach an edge server over wireless, edges reach the cloud over
backhaul, and aggregation can happen at both tiers.  This module makes that
graph a first-class :class:`Topology`, registered by name like the other
four axes (aggregators / allocators / compressors / scenarios):

  ``star``        the legacy flat FedsLLM graph (the default, bit-identical
                  to the pre-topology engine — no attachment, no backhaul)
  ``edge-cloud``  K clients → M edge servers → 1 cloud: the edge hosts the
                  server subnetwork (split-learning peer), the cloud hosts
                  the federated aggregator; every client's per-round fed
                  traffic transits its edge's backhaul link
  ``edge-agg``    like ``edge-cloud`` but the edge also pre-aggregates its
                  clients' LoRA deltas before the backhaul hop (two-tier
                  fedavg): the backhaul carries ONE delta per edge, and the
                  in-trace aggregation runs per edge then across edges
  ``relay``       clients sit behind relay nodes: the relay forwards ALL of
                  its clients' traffic (fed upload + per-iteration smashed
                  activations) over one shared uplink pipe

A topology owns three things:

  (a) *attachment* — which edge each client hangs off, by path loss against
      deterministic edge positions (a ring inside the cell), recomputed from
      each round's large-scale state so mobility (the ``drift`` scenario)
      re-attaches clients as they move;
  (b) *per-hop delay* — the wireless hop reuses the §III rate model against
      the client's **attached edge** (each edge owns an independent copy of
      the bandwidth pool — spatial reuse), the backhaul hop is a configured
      capacity; both compose into an end-to-end ``RoundTiming`` via the
      max-over-paths critical path (``repro.net.delay``);
  (c) *allocation* — problems (16)/(17) solved **per edge cell**: at fixed η
      each cell's bandwidth pool is an independent convex subproblem for the
      existing Lemma-3 machinery; a topology-level η sweep combines the
      cells under the hierarchical critical path (``repro.net.allocation``).

Everything here is host-side numpy (the simulator).  The only thing that
crosses into the jitted round function is the static-shaped one-hot
assignment matrix of the ``edge-agg`` two-tier aggregation — like the
straggler mask, it varies per round in value only, so the single-jit-trace
round contract holds.

    exp = Experiment.from_config(run_cfg, topology="edge-cloud",
                                 scenario="geo-blockfade")
    exp.run(num_rounds=20, stream=stream, reallocate=True)

Non-star topologies need a geometry-carrying scenario (``geo-blockfade``,
``drift``, ``hetero``, ``outage``, ``shadowing`` — anything built on
``realize_network``): the legacy ``blockfade``/``frozen`` draws don't record
user positions, so there is nothing to attach to (a ``ValueError`` says so).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Union

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import fedsllm
from repro.core.fedsllm import RoundTiming
from repro.core.resource_alloc import Allocation
from repro.des import queueing
from repro.net import allocation as hier_alloc
from repro.net import delay as hier_delay
from repro.registry import Registry

topologies: Registry = Registry("topology")


class Topology:
    """Base class: the flat (star) graph; subclasses add tiers.

    All methods must be pure in their arguments — campaigns re-derive the
    attachment every round from that round's network, so determinism in
    ``(seed, round)`` is inherited from the scenario that drew the network.
    """

    name = "topology"
    #: number of intermediate nodes (edges / relays); 0 = flat
    num_edges = 0
    #: whether the in-trace aggregation is two-tier (per-edge then cloud)
    two_tier = False

    # -- identity ----------------------------------------------------------
    def params(self) -> dict:
        """Constructor parameters that change the graph (digest input)."""
        return {}

    def digest(self, fcfg: FedsLLMConfig, scenario, seed: int) -> str:
        """Checkpoint identity: graph params + constructor-time attachment.

        Two campaigns that share a scenario draw but hang clients off
        different graphs (edge count, backhaul capacity, or a different
        attachment realisation) are different campaigns — resume must be
        able to tell them apart.
        """
        h = hashlib.sha1(repr(sorted(self.params().items())).encode())
        if self.num_edges:
            net = scenario.initial_network(fcfg, seed)
            assign = self.attach(fcfg, net)
            h.update(np.ascontiguousarray(assign, np.int64).tobytes())
        return h.hexdigest()[:16]

    # -- attachment --------------------------------------------------------
    def edge_xy(self, fcfg: FedsLLMConfig) -> Optional[np.ndarray]:
        """(M, 2) deterministic edge positions; None for the flat graph."""
        return None

    def attach(self, fcfg: FedsLLMConfig,
               net: dm.Network) -> Optional[np.ndarray]:
        """(K,) edge index per client (minimum path loss); None when flat."""
        return None

    def localize(self, fcfg: FedsLLMConfig, net: dm.Network
                 ) -> tuple[dm.Network, Optional[np.ndarray]]:
        """Re-anchor the wireless hop on the attached edge.

        Returns ``(net', assign)``: for the flat graph this is the identity;
        hierarchical graphs move each client's path loss from the BS to its
        nearest edge (the shadowing realisation is preserved — only the
        deterministic distance term changes), so every downstream consumer
        (allocator, retiming, deadline masks) prices the client→edge link.
        """
        return net, None

    # -- allocation + timing ----------------------------------------------
    def allocate(self, fcfg: FedsLLMConfig, net: dm.Network,
                 assign: Optional[np.ndarray], allocate_fn, *,
                 strategy: str = "proposed", population=None,
                 **kw) -> Allocation:
        """Solve (16)/(17) on this graph; flat = the legacy single-pool solve.

        ``population`` (the 9th axis, ``repro.pop``) is consumed here — NOT
        forwarded into ``allocate_fn`` — because the registered allocators
        know nothing about population models; hierarchical graphs hand it to
        the per-cell machinery which may restrict solves to representative
        clients.  The flat graph has no cells, so it is simply dropped.
        """
        del population
        return allocate_fn(fcfg, net, **kw)

    def round_timing(self, fcfg: FedsLLMConfig, net: dm.Network,
                     alloc: Allocation, eta: float,
                     assign: Optional[np.ndarray],
                     population=None) -> RoundTiming:
        """End-to-end per-client round time (max over the client's path)."""
        del population  # flat graph: no queues for a population model to price
        return fedsllm.simulate_round_time(fcfg, net, alloc, eta)

    def backhaul_seconds(self, fcfg: FedsLLMConfig,
                         assign: Optional[np.ndarray],
                         eta: float) -> np.ndarray:
        """(K,) per-client backhaul hop time this round; zeros when flat
        (``assign=None`` — the star graph has no second hop)."""
        return np.zeros(0 if assign is None else len(assign))

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"{type(self).__name__}({self.name!r})"


@topologies.register("star")
class StarTopology(Topology):
    """The legacy flat FedsLLM graph — every client one wireless hop from
    both servers, one shared bandwidth pool.  Bit-identical to the
    pre-topology engine (every method is the identity / legacy call)."""

    name = "star"


class HierTopology(Topology):
    """Shared machinery for multi-hop graphs: edge placement, attachment,
    localization and the per-cell allocator; subclasses define what the
    backhaul hop carries.

    ``placement`` picks where the M edges stand: ``"ring"`` (the legacy
    deterministic circle at ``area_m/4``) or ``"kmeans"`` — facility
    location over the drawn user geometry (Lloyd's algorithm seeded at the
    ring, so it is a pure deterministic function of the scenario's
    large-scale draw; mobility scenarios re-place per round with the rest
    of localization).  ``backhaul_model`` prices the edge→cloud hop:
    ``"serial"`` (the legacy fixed-capacity pipe — every cell member waits
    the full cell transfer), ``"fifo"`` or ``"ps"`` — the SHARED metro
    backhaul as a queueing resource (``repro.des.queueing``): cells contend,
    each transfer's arrival is its client's wireless completion, and the
    per-client hop is its own wait+service.  ``downlink_bps`` > 0 adds the
    per-round global-model broadcast cost (one multicast per cell,
    ``queueing.broadcast_seconds``); 0 keeps the paper's negligible-downlink
    convention.

    Under a queued backhaul the 'proposed' allocator closes the
    allocator↔queueing loop (``repro.net.allocation.solve_wait_aware``):
    ``wait_aware=False`` opts a queued-backhaul graph back into the legacy
    wait-blind per-cell solves (serial graphs never run the loop either
    way), ``wait_iters`` caps the deterministic fixed-point iteration and
    ``wait_damping`` ∈ (0, 1] is its update step.
    """

    def __init__(self, num_edges: int = 2, backhaul_bps: float = 200e6,
                 placement: str = "ring", backhaul_model: str = "serial",
                 downlink_bps: float = 0.0, wait_aware: bool = True,
                 wait_iters: int = 8, wait_damping: float = 0.5):
        if num_edges < 1:
            raise ValueError(f"num_edges must be ≥ 1, got {num_edges}")
        if backhaul_bps <= 0:
            raise ValueError(f"backhaul_bps must be > 0, got {backhaul_bps}")
        if placement not in ("ring", "kmeans"):
            raise ValueError(f"unknown placement {placement!r}; "
                             f"known: ['kmeans', 'ring']")
        if backhaul_model not in ("serial", "fifo", "ps"):
            raise ValueError(f"unknown backhaul_model {backhaul_model!r}; "
                             f"known: ['fifo', 'ps', 'serial']")
        if wait_iters < 1:
            raise ValueError(f"wait_iters must be ≥ 1, got {wait_iters}")
        if not 0.0 < wait_damping <= 1.0:
            raise ValueError(f"wait_damping must be in (0, 1], "
                             f"got {wait_damping}")
        self.num_edges = int(num_edges)
        self.backhaul_bps = float(backhaul_bps)
        self.placement = placement
        self.backhaul_model = backhaul_model
        self.downlink_bps = float(downlink_bps)
        self.wait_aware = bool(wait_aware)
        self.wait_iters = int(wait_iters)
        self.wait_damping = float(wait_damping)

    def params(self) -> dict:
        return {"num_edges": self.num_edges, "backhaul_bps": self.backhaul_bps,
                "placement": self.placement,
                "backhaul_model": self.backhaul_model,
                "downlink_bps": self.downlink_bps,
                "wait_aware": self.wait_aware,
                "wait_iters": self.wait_iters,
                "wait_damping": self.wait_damping}

    def edge_xy(self, fcfg: FedsLLMConfig,
                net: Optional[dm.Network] = None) -> np.ndarray:
        """(M, 2) edge positions.

        ``ring``: evenly spaced on a circle of radius ``area_m/4`` — a
        deterministic function of (M, area) so no RNG stream is consumed
        (the scenario owns every random draw).  ``kmeans``: Lloyd's
        facility location over the round's user geometry, initialised AT
        the ring — still RNG-free, pure in the scenario's draw, and it
        re-places edges as geometry evolves (``drift``)."""
        ang = 2.0 * np.pi * np.arange(self.num_edges) / self.num_edges
        r = fcfg.area_m / 4.0
        ring = np.stack([r * np.cos(ang), r * np.sin(ang)], axis=1)
        if self.placement == "ring":
            return ring
        if net is None or net.xy is None:
            raise ValueError(
                f"placement 'kmeans' places edges over the user geometry; "
                f"topology {self.name!r} got no positions (use a "
                f"geometry-carrying scenario like geo-blockfade)")
        return _lloyd(net.xy, ring)

    def attach(self, fcfg: FedsLLMConfig, net: dm.Network) -> np.ndarray:
        if net.xy is None:
            raise ValueError(
                f"topology {self.name!r} needs a geometry-carrying scenario "
                f"(Network.xy is None — the legacy 'blockfade'/'frozen' "
                f"draws don't record positions); use geo-blockfade, drift, "
                f"hetero, outage or shadowing")
        # nearest edge == minimum distance path loss (monotone in distance)
        d = np.linalg.norm(
            net.xy[:, None, :] - self.edge_xy(fcfg, net)[None, :, :], axis=2)
        return np.argmin(d, axis=1)

    def localize(self, fcfg: FedsLLMConfig, net: dm.Network
                 ) -> tuple[dm.Network, np.ndarray]:
        assign = self.attach(fcfg, net)
        exy = self.edge_xy(fcfg, net)[assign]
        # the SAME path-loss law that produced net.pl_db, on the relative
        # client→edge positions — keep the round's shadowing realisation
        # and swap only the distance term: g' = g · 10^((pl_bs − pl_edge)/10)
        pl_edge = dm.path_loss_db(fcfg, net.xy - exy)
        ratio = dm.db_to_lin(net.pl_db - pl_edge)
        return dataclasses.replace(net, g_c=net.g_c * ratio,
                                   g_s=net.g_s * ratio,
                                   pl_db=pl_edge), assign

    def allocate(self, fcfg: FedsLLMConfig, net: dm.Network,
                 assign: Optional[np.ndarray], allocate_fn, *,
                 strategy: str = "proposed", population=None,
                 **kw) -> Allocation:
        return hier_alloc.optimize_cells(fcfg, net, assign, self,
                                         allocate_fn, strategy=strategy,
                                         population=population, **kw)

    def round_timing(self, fcfg: FedsLLMConfig, net: dm.Network,
                     alloc: Allocation, eta: float,
                     assign: Optional[np.ndarray],
                     population=None) -> RoundTiming:
        wireless = fedsllm.simulate_round_time(fcfg, net, alloc, eta)
        return hier_delay.compose(
            wireless,
            self.backhaul_hop(fcfg, assign, eta,
                              np.asarray(wireless.total, float),
                              population=population),
            assign,
            self.downlink_hop(fcfg, assign))

    def backhaul_hop(self, fcfg: FedsLLMConfig, assign: np.ndarray,
                     eta: float, totals: np.ndarray,
                     population=None) -> np.ndarray:
        """(K,) backhaul hop given per-client wireless completion times —
        THE composition point for the edge→cloud leg (``round_timing`` and
        the pipelined execution schedule both price through it, so the
        serial-vs-queued dispatch lives in exactly one place).

        A ``population`` model (``repro.pop``) gets first refusal on the
        queued hop: ``meanfield`` replaces the exact per-job queue replay
        with its analytic per-cell arrival-rate model (O(K) vectorised,
        no O(K²) processor-sharing stepping).  A population returning
        ``None`` — or the serial pipe, which is already O(K) — falls back
        to the exact pricing unchanged.
        """
        if self.backhaul_model == "serial":
            return self.backhaul_seconds(fcfg, assign, eta)
        totals = np.asarray(totals, float)
        if population is not None:
            hop = population.queued_hop(self, fcfg, assign, eta, totals)
            if hop is not None:
                return hop
        return self._queued_backhaul(fcfg, assign, eta, totals)

    def downlink_hop(self, fcfg: FedsLLMConfig,
                     assign: np.ndarray) -> Optional[np.ndarray]:
        """(K,) per-round global-model broadcast cost, or None when
        disabled: one multicast per cell per round, cells broadcast in
        parallel, every member pays the same wait."""
        if self.downlink_bps <= 0:
            return None
        return np.full(len(assign), queueing.broadcast_seconds(
            fcfg.s_c_bits, self.downlink_bps))

    # -- per-edge traffic on the backhaul hop ------------------------------
    def _cell_bits(self, fcfg: FedsLLMConfig, assign: np.ndarray,
                   eta: float) -> np.ndarray:
        """(M,) bits each edge pushes over its backhaul per global round.

        Priced for the FULL attached population, matching the §III delay
        model's convention: every one of the K simulated clients trains each
        global round (the wireless bandwidth split is likewise solved for
        all K), and campaign cohorts subsample *that* priced round rather
        than re-pricing the network per cohort.
        """
        raise NotImplementedError

    def backhaul_seconds(self, fcfg: FedsLLMConfig,
                         assign: np.ndarray, eta: float) -> np.ndarray:
        """The legacy serial-pipe hop: (K,) per-client backhaul seconds —
        all of a cell's traffic shares its pipe, every member waits the
        full cell transfer (bit-identical to the pre-queueing engine; the
        default ``backhaul_model="serial"``)."""
        bits = self._cell_bits(fcfg, assign, eta)
        return (bits / self.backhaul_bps)[assign]

    def _backhaul_jobs(self, fcfg: FedsLLMConfig, assign: np.ndarray,
                       eta: float, totals: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The queue's job list: ``(arrivals, bits, job_of_client)``.

        Default: one job per client — its fed-traffic transfer arrives at
        the metro queue when its wireless round completes.  ``edge-agg``
        overrides with one job per edge (the pre-aggregated delta leaves
        once the whole cell has reported)."""
        K = len(assign)
        counts = np.bincount(assign, minlength=self.num_edges)
        per_client = (self._cell_bits(fcfg, assign, eta)
                      / np.maximum(counts, 1))[assign]
        return totals, per_client, np.arange(K)

    def _queued_backhaul(self, fcfg: FedsLLMConfig, assign: np.ndarray,
                         eta: float, totals: np.ndarray) -> np.ndarray:
        """(K,) backhaul hop under the SHARED metro queue (``fifo``/``ps``):
        cells contend for one ``backhaul_bps`` resource, and each client's
        hop is its own job's wait + service (``repro.des.queueing``)."""
        arrivals, bits, job_of = self._backhaul_jobs(fcfg, assign, eta,
                                                     totals)
        if self.backhaul_model == "fifo":
            completion, _ = queueing.fifo(
                arrivals, queueing.service_seconds(bits, self.backhaul_bps))
        else:  # "ps"
            completion = queueing.processor_sharing(
                arrivals, np.asarray(bits, float), rate=self.backhaul_bps)
        # an outage'd client (wireless total +inf) never reaches the queue:
        # its hop is 0 so the composed path stays +inf instead of inf−inf
        hop = np.zeros_like(totals)
        finite = np.isfinite(totals)
        hop[finite] = completion[job_of][finite] - totals[finite]
        return hop


@topologies.register("edge-cloud")
class EdgeCloudTopology(HierTopology):
    """K clients → M edges → 1 cloud (SplitLLM-style).

    The edge hosts the server subnetwork: the per-iteration smashed
    activations (``s`` bits) terminate at the edge.  The cloud hosts the
    federated aggregator: each client's per-round LoRA delta (``s_c`` bits)
    transits the edge's backhaul, serialised with its cellmates'."""

    name = "edge-cloud"

    def _cell_bits(self, fcfg, assign, eta):
        counts = np.bincount(assign, minlength=self.num_edges)
        return counts * fcfg.s_c_bits


@topologies.register("edge-agg")
class EdgeAggTopology(HierTopology):
    """``edge-cloud`` plus edge-side pre-aggregation (two-tier fedavg).

    The edge averages its clients' LoRA deltas before the backhaul hop, so
    the backhaul carries ONE ``s_c`` payload per edge regardless of cell
    size, and the in-trace aggregation becomes per-edge → cross-edge
    (``federated.hier_aggregate``; the cohort's one-hot assignment matrix is
    a value-only round-function argument, like the straggler mask)."""

    name = "edge-agg"
    two_tier = True

    def _cell_bits(self, fcfg, assign, eta):
        return np.full(self.num_edges, fcfg.s_c_bits)

    def _backhaul_jobs(self, fcfg, assign, eta, totals):
        # one pre-aggregated delta per NON-EMPTY edge; it leaves for the
        # cloud once the cell's slowest DEADLINE-SURVIVING member has
        # reported, and every member of the cell rides its edge's job.  An
        # outage'd member (+inf wireless total) never reports and is exactly
        # the client the deadline mask drops — the edge aggregates without
        # it, so it must not hold every finite cellmate's hop at +inf.  The
        # arrival is +inf only when the WHOLE cell is outage'd.
        edges = np.unique(assign)
        arrivals = np.array([_finite_max(totals[assign == m]) for m in edges])
        job_of = np.searchsorted(edges, assign)
        return arrivals, np.full(len(edges), fcfg.s_c_bits), job_of


@topologies.register("relay")
class RelayTopology(HierTopology):
    """Clients behind relay nodes sharing one uplink pipe each.

    The relay is a pure forwarder: everything a client sends — the
    per-round fed delta AND every local iteration's smashed activations —
    transits the relay's uplink, serialised with its cellmates'.  The
    backhaul load therefore scales with Lemma 2's V(η) local-iteration
    count, which couples the relay hop into the η sweep."""

    name = "relay"

    def __init__(self, num_edges: int = 2, backhaul_bps: float = 50e6, **kw):
        super().__init__(num_edges=num_edges, backhaul_bps=backhaul_bps, **kw)

    def _cell_bits(self, fcfg, assign, eta):
        counts = np.bincount(assign, minlength=self.num_edges)
        V = dm.local_iters(fcfg, eta)
        return counts * (fcfg.s_c_bits + V * fcfg.s_bits)


def _finite_max(x: np.ndarray) -> float:
    """max over the finite entries; +inf when none are finite."""
    x = np.asarray(x, float)
    x = x[np.isfinite(x)]
    return float(np.max(x)) if x.size else np.inf


def _lloyd(xy: np.ndarray, init_centroids: np.ndarray,
           iters: int = 32) -> np.ndarray:
    """Deterministic Lloyd's k-means over user positions (facility location).

    Initialised at the caller's centroids (the ring), no RNG: the result is
    a pure function of the geometry, so campaigns stay reproducible and the
    checkpoint digest covers the placement through the attachment it
    induces.  Empty clusters keep their previous centroid (the ring point —
    it simply attracts nobody)."""
    cent = np.asarray(init_centroids, float).copy()
    for _ in range(iters):
        d = np.linalg.norm(xy[:, None, :] - cent[None, :, :], axis=2)
        lab = np.argmin(d, axis=1)
        new = cent.copy()
        for m in range(len(cent)):
            members = xy[lab == m]
            if len(members):
                new[m] = members.mean(axis=0)
        if np.allclose(new, cent, rtol=0, atol=1e-9):
            break
        cent = new
    return cent


def get_topology(spec: Union[str, Topology]) -> Topology:
    """Resolve a topology name or pass an instance through.

    ``get_topology("edge-cloud")`` → the registered default instance;
    ``get_topology(EdgeCloudTopology(num_edges=4))`` → the object itself.
    Unknown names raise ``KeyError`` listing the registered names.
    """
    if isinstance(spec, Topology):
        return spec
    if isinstance(spec, type) and issubclass(spec, Topology):
        return spec()
    cls = topologies.get(spec)
    return cls()
