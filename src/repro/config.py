"""Configuration system.

Dataclass-based, immutable configs with a global registry so launchers can do
``--arch starcoder2-7b --shape train_4k``.  Every assigned architecture gets a
module in ``repro/configs/`` that registers its exact published config.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

# ---------------------------------------------------------------------------
# LoRA (the paper's parameter-efficient fine-tuning substrate)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LoRAConfig:
    """Low-rank adaptation (paper eq. (1): w0 + B·A, r << min(d, k))."""

    rank: int = 16
    alpha: float = 32.0
    # Which projection weights receive adapters.  Matched by leaf-name suffix;
    # covers attention/MLP (dense, MoE experts), Mamba-2 (in/out_proj) and
    # RG-LRU (w_rec_in/w_gate_in/w_out) families.
    targets: tuple[str, ...] = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                                "in_proj", "out_proj", "w_rec_in", "w_gate_in", "w_out")
    dropout: float = 0.0  # kept for API completeness; 0 in all experiments

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """One config object covers every family in the zoo.

    ``family`` selects the block builder in ``models/registry.py``:
      dense   — standard decoder-only transformer (GQA + RoPE)
      moe     — dense attention + top-k routed expert MLP
      ssm     — Mamba-2 (SSD) attention-free stack
      hybrid  — RecurrentGemma: RG-LRU recurrent blocks : local attention, 1:2
      encdec  — whisper-style encoder-decoder (frame-embedding frontend stub)
      vlm     — LLaVA-NeXT: vision patch-embedding stub + decoder LM backbone
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qk_norm: bool = False  # qwen3-style RMSNorm on q/k
    attn_logit_softcap: float = 0.0  # gemma2: 50.0 (0 = off)
    final_logit_softcap: float = 0.0  # gemma2: 30.0 (0 = off)
    sliding_window: int = 0  # 0 = global attention
    # Per-layer-group pattern, tiled over depth. "G"=global attn, "L"=local
    # (sliding-window) attn, "R"=recurrent (RG-LRU), "M"=mamba2 SSD block.
    layer_pattern: str = "G"

    # --- block options -----------------------------------------------------
    mlp_activation: str = "swiglu"  # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    use_bias: bool = False
    use_post_norm: bool = False  # gemma2 pre+post sandwich norms
    parallel_block: bool = False  # command-r parallel attn+mlp
    tie_embeddings: bool = False
    embedding_multiplier: float = 1.0  # gemma family: sqrt(d_model)
    logit_scale: float = 1.0  # command-r: 0.0625

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_router_norm: bool = True  # normalise top-k router weights

    # --- SSM (mamba2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (RG-LRU) ----------------------------------------------------
    lru_width: int = 0  # 0 -> d_model

    # --- enc-dec ------------------------------------------------------------
    num_encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s of audio -> 1500 frames

    # --- VLM ----------------------------------------------------------------
    vision_tokens: int = 0  # anyres stub: number of precomputed patch embeds

    # --- numerics / training ------------------------------------------------
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "bfloat16"
    lora: Optional[LoRAConfig] = None

    # ------------------------------------------------------------------

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    @property
    def pattern(self) -> str:
        """Layer-type pattern tiled to full depth."""
        p = self.layer_pattern
        reps = -(-self.num_layers // len(p))
        return (p * reps)[: self.num_layers]

    @property
    def group_size(self) -> int:
        """Layers per scan group (one copy of the pattern)."""
        return len(self.layer_pattern)

    @property
    def num_groups(self) -> int:
        """Full scanned groups; remainder layers are an unscanned tail."""
        return self.num_layers // self.group_size

    def param_count(self, trainable_only: bool = False) -> int:
        """Analytic parameter count (used by the delay model + roofline)."""
        from repro.models.registry import count_params

        return count_params(self, trainable_only=trainable_only)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Archs allowed to run long_500k (sub-quadratic sequence mixing only).
LONG_CONTEXT_OK = ("mamba2-130m", "recurrentgemma-9b")


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True


# ---------------------------------------------------------------------------
# Training / run config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1_000
    grad_clip: float = 1.0
    optimizer: str = "adamw"  # adamw | sgd | adafactor
    remat: str = "full"  # none | full | dots
    seed: int = 0
    microbatch: int = 0  # 0 = no accumulation
    moment_dtype: str = "float32"


@dataclass(frozen=True)
class FedsLLMConfig:
    """Paper Section III/IV settings (defaults = the paper's simulation)."""

    num_clients: int = 50
    area_m: float = 500.0  # 500 m x 500 m square, BS at centre
    split_ratio_min: float = 0.1  # A_min
    split_ratio_max: float = 0.9  # A_max
    # Lemma constants
    xi: float = 0.1  # ξ
    delta: float = 0.1  # δ (local GD step size)
    epsilon0: float = 1e-3  # ε0 target global accuracy
    L_smooth: float = 1.0  # L (Lipschitz)
    gamma_strong: float = 1.0  # γ (strong convexity)
    # channel / radio
    bandwidth_total_hz: float = 20e6  # B_c = B_s = 20 MHz
    noise_psd_dbm_hz: float = -174.0  # N0
    pathloss_const_db: float = 128.1
    pathloss_exp: float = 37.6  # 128.1 + 37.6 log10(d_km)
    shadow_std_db: float = 8.0
    p_max_dbm: float = 10.0  # per-user max tx power
    # compute
    f_max_hz: float = 2e9  # client CPU 2 GHz
    f_server_hz: float = 1e10  # main server (>> clients)
    cycles_per_param_low: float = 1e4  # C_k ~ U[1,3]x1e4
    cycles_per_param_high: float = 3e4
    kappa: float = 1e-28  # effective switched capacitance
    # data volumes
    s_c_bits: float = 28.1e3  # client->fed server per round
    s_bits: float = 281e3  # client->main server per local iteration
    # dataset
    num_samples: int = 60_021  # BlogFeedback [12]
    sample_dim: int = 281
    # eta sweep
    eta_step: float = 0.01
    # training-η policy (repro.api.Experiment): η* from the allocator is
    # clamped to ≤ eta_train_max so Lemma 2 keeps a non-trivial local
    # iteration count; joint per-round re-solves (reallocate=True) quantize
    # the adopted η to the eta_bucket grid so the campaign reuses one jitted
    # round function per bucket instead of recompiling every round
    eta_train_max: float = 0.5
    eta_bucket: float = 0.05


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    fedsllm: Optional[FedsLLMConfig] = None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> ModelConfig:
    _ensure_configs_imported()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    _ensure_configs_imported()
    return sorted(_REGISTRY)


def _ensure_configs_imported():
    # configs register themselves on import
    import repro.configs  # noqa: F401


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    kw: dict[str, Any] = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, len(cfg.layer_pattern)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.num_experts:
        kw.update(num_experts=8, num_experts_per_tok=2)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "hybrid":
        kw.update(lru_width=64, sliding_window=32)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.family == "encdec":
        kw.update(num_encoder_layers=2, encoder_seq=32)
    if cfg.family == "vlm":
        kw.update(vision_tokens=8)
    return cfg.replace(**kw)
