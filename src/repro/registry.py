"""Generic named-strategy registry.

One mechanism backs every pluggable axis of the unified ``Experiment`` API
(aggregators, allocators, compressors), mirroring ``config.register_arch``:
strategies register themselves by name at import time, lookups of unknown
names raise a ``KeyError`` that lists the known names.
"""

from __future__ import annotations

from typing import Callable, Generic, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, T] = {}

    def register(self, name: str) -> Callable[[T], T]:
        """Decorator: ``@registry.register("name")`` on a strategy."""

        def deco(obj: T) -> T:
            if name in self._entries:
                raise ValueError(f"duplicate {self.kind} {name!r}")
            self._entries[name] = obj
            return obj

        return deco

    def get(self, name: str) -> T:
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} {name!r}; known: {sorted(self._entries)}")
        return self._entries[name]

    def names(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries
