"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427]: 38L, d=4096, 16H MQA(kv=1),
ff=12288, lru_width=4096, local attention window 2048, pattern 2 recurrent :
1 local-attention (RRL). GeGLU, RMSNorm, embedding multiplier sqrt(d)."""

import math

from repro.config import ModelConfig, register_arch


@register_arch("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,  # = 12 x (R,R,L) + (R,R) tail
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        mlp_activation="geglu",
        norm_type="rmsnorm",
        use_rope=True,
        rope_theta=10_000.0,
        layer_pattern="RRL",
        sliding_window=2048,
        lru_width=4096,
        tie_embeddings=True,
        embedding_multiplier=math.sqrt(4096.0),
    )
