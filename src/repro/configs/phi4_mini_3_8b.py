"""Phi-4-mini 3.8B [arXiv:2412.08905]: 32L, d=3072, 24H GQA(kv=8), ff=8192,
vocab=200064. RoPE + SwiGLU + GQA, RMSNorm."""

from repro.config import ModelConfig, register_arch


@register_arch("phi4-mini-3.8b")
def phi4_mini() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200_064,
        mlp_activation="swiglu",
        norm_type="rmsnorm",
        use_rope=True,
        rope_theta=10_000.0,
        layer_pattern="G",
        tie_embeddings=True,
    )
