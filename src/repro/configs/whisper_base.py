"""Whisper-base [arXiv:2212.04356]: enc-dec, 6L each, d=512, 8H MHA, ff=2048,
vocab=51865. Conv audio frontend is a STUB: input_specs provides precomputed
frame embeddings (B, 1500, 512). Learned positional embeddings, GELU,
LayerNorm. Decoder cross-attends to the encoder."""

from repro.config import ModelConfig, register_arch


@register_arch("whisper-base")
def whisper_base() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        num_layers=6,
        num_encoder_layers=6,
        encoder_seq=1500,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51_865,
        mlp_activation="gelu",
        norm_type="layernorm",
        use_bias=True,
        use_rope=False,  # learned absolute positions
        layer_pattern="G",
        tie_embeddings=True,
    )
