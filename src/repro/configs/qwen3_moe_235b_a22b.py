"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-235B-A22B family]: 94L, d=4096, 64H
GQA(kv=4), expert ff=1536, vocab=151936, 128 experts top-8. QK-norm, SwiGLU
experts, RoPE, RMSNorm."""

from repro.config import ModelConfig, register_arch


@register_arch("qwen3-moe-235b-a22b")
def qwen3_moe() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab_size=151_936,
        mlp_activation="swiglu",
        norm_type="rmsnorm",
        use_rope=True,
        rope_theta=1e6,
        qk_norm=True,
        layer_pattern="G",
        num_experts=128,
        num_experts_per_tok=8,
    )
