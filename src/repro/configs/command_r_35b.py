"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]: 40L, d=8192, 64H
GQA(kv=8), ff=22528, vocab=256000. No-bias LayerNorm, parallel attn+mlp
blocks (Cohere style), tied embeddings with logit scale 0.0625."""

from repro.config import ModelConfig, register_arch


@register_arch("command-r-35b")
def command_r_35b() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22528,
        vocab_size=256_000,
        mlp_activation="swiglu",
        norm_type="layernorm",
        use_bias=False,
        use_rope=True,
        rope_theta=8e6,
        layer_pattern="G",
        parallel_block=True,
        tie_embeddings=True,
        logit_scale=0.0625,
    )
