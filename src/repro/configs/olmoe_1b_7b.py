"""OLMoE-1B-7B [arXiv:2409.02060]: 16L, d=2048, 16H (kv=16 -> MHA), expert
ff=1024, vocab=50304, 64 experts top-8. RMSNorm + SwiGLU experts + RoPE +
qk-norm."""

from repro.config import ModelConfig, register_arch


@register_arch("olmoe-1b-7b")
def olmoe() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        mlp_activation="swiglu",
        norm_type="rmsnorm",
        use_rope=True,
        rope_theta=10_000.0,
        qk_norm=True,
        layer_pattern="G",
        num_experts=64,
        num_experts_per_tok=8,
    )
