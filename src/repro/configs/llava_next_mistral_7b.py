"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
32L, d=4096, 32H GQA(kv=8), ff=14336, vocab=32000. Anyres tiling vision
frontend is a STUB: input_specs provides precomputed patch embeddings
(CLIP-L width 1024), projected by a 2-layer MLP into the LM stream."""

from repro.config import ModelConfig, register_arch


@register_arch("llava-next-mistral-7b")
def llava_next() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        mlp_activation="swiglu",
        norm_type="rmsnorm",
        use_rope=True,
        rope_theta=1e6,
        layer_pattern="G",
        vision_tokens=2880,  # anyres: 576 base + 4 x 576 tile patches
    )
