"""Mamba2-130M [arXiv:2405.21060]: 24L, d=768, attention-free SSD,
ssm_state=128, vocab=50280. expand=2 -> d_inner=1536, head_dim=64 (24 heads),
chunk=256."""

from repro.config import ModelConfig, register_arch


@register_arch("mamba2-130m")
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=12,  # unused by SSD path (ssm heads derived from expand*d/hd)
        num_kv_heads=12,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        norm_type="rmsnorm",
        use_rope=False,
        layer_pattern="M",
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        tie_embeddings=True,
    )
