"""StarCoder2-7B [arXiv:2402.19173]: 32L, d=4608, 36H GQA(kv=4), ff=18432,
vocab=49152. GQA + RoPE, GELU MLP, LayerNorm with bias (starcoder2 style)."""

from repro.config import ModelConfig, register_arch


@register_arch("starcoder2-7b")
def starcoder2_7b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        mlp_activation="gelu",
        norm_type="layernorm",
        use_bias=True,
        use_rope=True,
        rope_theta=1e5,
        layer_pattern="G",
    )
