"""Architecture configs — importing this package registers all archs."""

from repro.configs import (  # noqa: F401
    command_r_35b,
    fedsllm_paper,
    gemma2_9b,
    llava_next_mistral_7b,
    mamba2_130m,
    olmoe_1b_7b,
    phi4_mini_3_8b,
    qwen3_moe_235b_a22b,
    recurrentgemma_9b,
    starcoder2_7b,
    whisper_base,
)
