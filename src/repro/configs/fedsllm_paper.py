"""The paper's own experimental setting.

The paper does not pin a specific LLM architecture — it fine-tunes "a large
language model" with LoRA on the BlogFeedback dataset [12] (60 021 samples x
281 dims) with 50 users over a 20 MHz FDMA uplink.  We register (a) the
wireless/simulation config exactly as in §IV, and (b) a ~100M decoder LM used
by the end-to-end training examples (small enough to train a few hundred
steps on this CPU container, structured like the assigned archs)."""

from repro.config import FedsLLMConfig, LoRAConfig, ModelConfig, register_arch

# Paper §IV simulation constants (see FedsLLMConfig defaults for the full set)
PAPER_SIM = FedsLLMConfig()


@register_arch("fedsllm-100m")
def fedsllm_100m() -> ModelConfig:
    return ModelConfig(
        name="fedsllm-100m",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32_000,
        mlp_activation="swiglu",
        norm_type="rmsnorm",
        use_rope=True,
        layer_pattern="G",
        lora=LoRAConfig(rank=16, alpha=32.0),
    )
