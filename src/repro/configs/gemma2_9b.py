"""Gemma-2 9B [arXiv:2408.00118]: 42L, d=3584, 16H GQA(kv=8), head_dim=256,
ff=14336, vocab=256000. Alternating local(4096)/global attention, attn logit
softcap 50, final softcap 30, GeGLU, pre+post sandwich norms, tied embeddings,
embedding multiplier sqrt(d)."""

import math

from repro.config import ModelConfig, register_arch


@register_arch("gemma2-9b")
def gemma2_9b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        mlp_activation="geglu",
        norm_type="rmsnorm",
        use_rope=True,
        rope_theta=10_000.0,
        layer_pattern="LG",  # local, global alternating
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        use_post_norm=True,
        tie_embeddings=True,
        embedding_multiplier=math.sqrt(3584.0),
    )
