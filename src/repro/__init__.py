"""repro: FedsLLM — Federated Split Learning for LLMs over Communication Networks.

A production-grade JAX framework implementing the FedsLLM paper (Zhao et al.,
2024): LoRA + split-fed learning with wireless-network delay optimisation,
plus a 10-architecture model zoo, multi-pod sharding, Pallas TPU kernels,
checkpointing and serving.
"""

__version__ = "1.0.0"
