"""Execution schedules — the 6th pluggable strategy axis.

The campaign engine used to be hard-wired round-synchronous: every global
round waits for its slowest cohort member (or cuts it at the deadline), so
the server idles while clients compute and vice versa.  Related systems
show the synchronisation barrier is where the wall-clock goes — pipelining
client/server computation (arXiv 2504.14667) and exploiting asynchronous
client completion (FedAsync/FedBuff) both cut fine-tuning latency without
touching the learning rule.  This module makes the *execution discipline* a
first-class :class:`Schedule`, registered by name like the other five axes
(aggregators / allocators / compressors / scenarios / topologies):

  ``sync``        the round-synchronous default — replays today's campaign
                  event order through the event engine and is bit-identical
                  to the pre-schedule trajectories (tests pin this)
  ``pipelined``   GPipe-across-the-wireless-split: the client's forward of
                  microbatch i+1 overlaps the server's compute of microbatch
                  i, so each local iteration costs ``max(stage) +
                  (sum−max)/M`` instead of ``sum`` (§III decomposition via
                  ``repro.parallel.pipeline``) — simulated round wall-clock
                  strictly drops whenever at least two stages are non-zero
  ``async``       no barrier at all: clients rejoin immediately on
                  completion and the server aggregates each arrival with the
                  staleness-discounted weight w ∝ D_k/(1+staleness)^β
                  (``federated.staleness_weighted``); campaign round r is
                  the r-th aggregation event
  ``semi-async``  FedBuff-style buffer-K: the server buffers arrivals and
                  aggregates once ``buffer_k`` updates are in, each
                  staleness-discounted

A schedule decides three things per campaign round — which client states
feed the aggregation (the survivor mask + ``client_ids``), at what weight
(the staleness ``weight_scale`` folded onto D_k), and what the round costs
on the simulated clock (``round_time`` + the per-event trace).  Everything
is host-side: masks and weights enter the jitted round function through its
existing value-only arguments, so ``trace_count`` bounds are unchanged
under every schedule (asserted in ``tests/test_des.py``).

The asynchronous schedules run a deterministic discrete-event timeline
(:mod:`repro.des.engine`) over the whole campaign, pricing each client's
j-th run by the scenario's round-j realisation (``events.round_state`` — a
pure function of ``(RunConfig, seed, j)``), so campaigns stay pure in
``(RunConfig, seed)`` and checkpoint resume replays the identical timeline
(the same re-run-from-round-0 idiom as the ``drift`` walk).

    exp = Experiment.from_config(run_cfg, schedule="pipelined")
    exp.run(num_rounds=20, stream=stream)      # wall-clock drops vs sync

Unknown names raise ``KeyError`` listing the knowns, like every registry.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import numpy as np

from repro.core import delay_model as dm
from repro.core import federated
from repro.des.engine import EventSim
from repro.parallel import pipeline as pl
from repro.registry import Registry
from repro.sim import events as sim_events

schedules: Registry = Registry("schedule")


@dataclass
class RoundPlan:
    """What the schedule decided for one campaign round (host-side)."""

    round: int  # absolute global-round index (= aggregation index)
    mask: Optional[np.ndarray]  # (C,) aggregation survivors; None = all
    round_time: float  # simulated seconds this round costs the server
    client_ids: Optional[np.ndarray] = None  # override cohort (None = loop's)
    weight_scale: Optional[np.ndarray] = None  # (C,) staleness discounts on D_k
    update_scale: Optional[float] = None  # server mixing rate α on the update
    staleness: Optional[np.ndarray] = None  # (C,) versions behind, survivors
    completion: Optional[np.ndarray] = None  # (C,) per-client completion, s
    events: Optional[list] = None  # per-event timing records (dicts, in order)


class Schedule:
    """Base class: how client work and server aggregation interleave.

    All methods must be pure in their arguments — determinism in
    ``(seed, round)`` is part of the registry contract (property-tested for
    every registered name), and checkpoint resume relies on a re-planned
    schedule reproducing the interrupted timeline exactly.
    """

    name = "schedule"

    def params(self) -> dict:
        """Constructor parameters that change the discipline (doc/digest)."""
        return {}

    def planner(self, exp, *, campaign_seed: int, start: int, target: int,
                cohort: int, fixed_cohort: Optional[int],
                deadline: Optional[float], resample_channel: bool,
                reallocate: bool, realloc_search: str):
        """A per-campaign planner: ``planner.round_plan(r, ids)`` → plan.

        The default (synchronous family) planner prices each round from the
        experiment's CURRENT state — the campaign loop has already advanced
        ``exp.net/alloc/timing`` to round ``r`` when it asks.  Timeline
        schedules (async) override this and pre-simulate the whole
        campaign's event order instead.
        """
        return _PerRoundPlanner(self, exp, deadline)

    def _plan(self, exp, round_idx: int, ids: np.ndarray,
              deadline: Optional[float]) -> RoundPlan:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"{type(self).__name__}({self.name!r})"


class _PerRoundPlanner:
    """Planner for schedules that only need the current round's pricing."""

    def __init__(self, schedule: Schedule, exp, deadline: Optional[float]):
        self._schedule = schedule
        self._exp = exp
        self._deadline = deadline

    def round_plan(self, round_idx: int, ids: np.ndarray) -> RoundPlan:
        return self._schedule._plan(self._exp, round_idx, ids, self._deadline)


class _TimelinePlanner:
    """Planner for schedules that pre-simulated the campaign timeline.

    ``pricing`` maps round index → the ``events.round_state`` tuple the
    timeline already computed for that round; the campaign loop consumes it
    instead of re-pricing (under ``reallocate=True`` that would mean two
    full (16)/(17) solves per round)."""

    def __init__(self, plans: dict[int, RoundPlan], pricing: dict):
        self._plans = plans
        self.pricing = pricing

    def round_plan(self, round_idx: int, ids: np.ndarray) -> RoundPlan:
        return self._plans[round_idx]


def _mask_and_clock(completion: np.ndarray, deadline: Optional[float]
                    ) -> tuple[Optional[np.ndarray], float]:
    """The legacy straggler arithmetic on a vector of completion times —
    byte-identical to ``events.straggler_mask`` + ``round_wall_clock``."""
    mask = (None if deadline is None
            else federated.deadline_mask(completion, deadline))
    slowest = float(np.max(completion))
    return mask, (slowest if deadline is None else min(slowest, float(deadline)))


def _completion_trace(completion: np.ndarray, ids: np.ndarray,
                      round_time: float) -> list[dict]:
    """The round's event record: one completion per cohort client (popped in
    ``(time, seq)`` order by the engine) plus the server aggregation."""
    sim = EventSim()
    for pos, k in enumerate(ids):
        sim.schedule(float(completion[pos]), "complete", client=int(k))
    sim.schedule(float(round_time), "aggregate")
    return [{"t": e.time, "kind": e.kind, **e.data} for e in sim.run()]


@schedules.register("sync")
class SyncSchedule(Schedule):
    """The round-synchronous default — bit-identical to the pre-schedule
    engine.  Completion events are the §III per-client round totals; the
    survivor mask and round wall-clock derive from them with the exact
    arithmetic the legacy ``events.straggler_mask``/``round_wall_clock``
    used, so every existing campaign golden reproduces bit-for-bit."""

    name = "sync"

    def _plan(self, exp, round_idx, ids, deadline):
        completion = np.asarray(exp.timing.total, float)[ids]
        mask, round_time = _mask_and_clock(completion, deadline)
        return RoundPlan(round=round_idx, mask=mask, round_time=round_time,
                         completion=completion,
                         events=_completion_trace(completion, ids, round_time))


@schedules.register("pipelined")
class PipelinedSchedule(Schedule):
    """Microbatch-pipelined split execution (GPipe across the wireless cut).

    Each local iteration's sequential chain — client fwd → uplink → server
    fwd/bwd → client bwd — is split into ``num_microbatches`` slices so the
    client's forward of microbatch i+1 overlaps the server's compute of
    microbatch i: per-iteration cost drops from ``sum(stages)`` to
    ``max(stage) + (sum − max)/M`` (``repro.parallel.pipeline``).  The §III
    stage decomposition keeps the paper's negligible-downlink convention
    (``downlink_frac=0``), so the M=1 degenerate case reproduces eq. (15)'s
    round total exactly and any M>1 strictly improves it whenever at least
    two stages are non-zero.  The fed uplink ``t_c`` (once per round) and
    any backhaul/downlink hop of a hierarchical path are outside the
    per-iteration loop and unchanged.  Aggregation semantics are untouched
    — only completion times (hence straggler masks and the round clock)
    move.
    """

    name = "pipelined"

    def __init__(self, num_microbatches: int = 4):
        if num_microbatches < 1:
            raise ValueError(
                f"num_microbatches must be ≥ 1, got {num_microbatches}")
        self.num_microbatches = int(num_microbatches)

    def params(self):
        return {"num_microbatches": self.num_microbatches}

    def pipelined_totals(self, fcfg, net, alloc, eta: float) -> np.ndarray:
        """(K,) per-client WIRELESS round completion under pipelined local
        iterations — pure in its arguments: the §III stage decomposition of
        ``(net, alloc, η)`` with the per-iteration overlap applied."""
        stages = pl.split_stage_times(fcfg, net, eta, alloc.A, alloc,
                                      downlink_frac=0.0)
        per_iter = pl.pipeline_round_time(stages, self.num_microbatches)
        V = dm.local_iters(fcfg, eta)
        return (np.asarray(alloc.t_c, float)
                + V * np.asarray(per_iter["pipelined_s"], float))

    def completion_times(self, exp) -> np.ndarray:
        """(K,) pipelined end-to-end completions at the experiment's current
        pricing.  Hierarchical hops compose on top of the PIPELINED
        wireless completions: the serial pipe is arrival-independent, but
        the queueing backhaul models (``backhaul_model="fifo"/"ps"``) see
        the pipelined arrival times — re-using the sync-arrival waits would
        mix two timelines."""
        fcfg, eta, topo = exp.fcfg, exp.eta, exp.topology
        total = self.pipelined_totals(fcfg, exp.net, exp.alloc, eta)
        if getattr(topo, "num_edges", 0) and exp.assign is not None:
            total = total + topo.backhaul_hop(fcfg, exp.assign, eta, total)
            dl = topo.downlink_hop(fcfg, exp.assign)
            if dl is not None:  # broadcast cost is arrival-independent
                total = total + np.asarray(dl, float)
        return total

    def _plan(self, exp, round_idx, ids, deadline):
        completion = self.completion_times(exp)[ids]
        mask, round_time = _mask_and_clock(completion, deadline)
        return RoundPlan(round=round_idx, mask=mask, round_time=round_time,
                         completion=completion,
                         events=_completion_trace(completion, ids, round_time))


@schedules.register("async")
class AsyncSchedule(Schedule):
    """Fully asynchronous execution: no round barrier, immediate rejoin.

    All K simulated clients compute continuously; each *valid* completion
    (within the deadline, when one is set) is an arrival at the server.
    The server aggregates every ``buffer_k`` arrivals (1 here — FedAsync;
    ``semi-async`` raises it — FedBuff) and bumps the global version;
    campaign round r is the r-th aggregation.  An arrival that started at
    version v and lands at version r carries staleness r − v; the
    discount ``1/(1+staleness)^β`` enters TWICE, because the weighted mean
    normalizes: relatively, as ``D_k``-weight scaling among the buffered
    arrivals (``federated.staleness_weighted``'s rule, pre-folded into the
    round function's value-only ``weights`` argument — only meaningful at
    ``buffer_k ≥ 2``), and absolutely, as the server mixing rate
    ``α = mean discount`` applied to the aggregated update
    (Δw ← Δw + α·h̄ — the FedAsync damping; with a single arrival a weight
    discount alone would cancel in the normalization).  A client whose run
    would exceed the deadline is cancelled at the deadline and restarts
    fresh (an explicit ``timeout`` event in the trace).

    The whole timeline is one deterministic event simulation: client k's
    j-th run lasts the §III round total of the scenario's round-j
    realisation (``events.round_state`` — pure in ``(seed, j)``), so two
    runs of the same config produce byte-identical timelines and resume
    replays exactly.  With ``server_ps=True`` the main-server GPU is an
    egalitarian processor-sharing resource: immediate rejoin keeps all K
    clients concurrently active, so each run's server-compute share
    stretches by the population factor (the exact PS fluid limit at
    constant concurrency — see ``repro.des.queueing.processor_sharing``).

    The round function still steps the full population each aggregation
    (``client_ids`` = all K; the mask selects the arrivals), so the cohort
    argument does not subsample under async schedules — batch shapes stay
    fixed and ``trace_count`` bounds are unchanged.
    """

    name = "async"
    buffer_k = 1

    def __init__(self, beta: float = 0.5, buffer_k: Optional[int] = None,
                 server_ps: bool = False):
        if beta < 0:
            raise ValueError(f"staleness beta must be ≥ 0, got {beta}")
        self.beta = float(beta)
        if buffer_k is not None:
            if buffer_k < 1:
                raise ValueError(f"buffer_k must be ≥ 1, got {buffer_k}")
            self.buffer_k = int(buffer_k)
        self.server_ps = bool(server_ps)

    def params(self):
        return {"beta": self.beta, "buffer_k": self.buffer_k,
                "server_ps": self.server_ps}

    # -- per-run pricing ---------------------------------------------------
    def _duration_table(self, exp, campaign_seed, resample, reallocate,
                        realloc_search):
        """j → (K,) run durations, lazily priced and cached per round index
        (pure in ``(exp constructor state, seed, j)``).  Returns the lookup
        fn plus the raw per-round pricing tuples, which the campaign loop
        re-uses instead of re-solving (``_TimelinePlanner.pricing``)."""
        base_alloc = exp.alloc
        cache: dict[int, np.ndarray] = {}
        pricing: dict[int, tuple] = {}

        def durations(j: int) -> np.ndarray:
            if j not in cache:
                state = sim_events.round_state(
                    exp, campaign_seed, j, base_alloc=base_alloc,
                    resample=resample, reallocate=reallocate,
                    realloc_search=realloc_search)
                pricing[j] = state
                net, assign, alloc, eta, timing = state
                total = np.asarray(timing.total, float)
                K = len(total)
                if self.server_ps and K > 1:
                    # PS fluid limit at constant concurrency K: the server
                    # share (1−A)·E·log2(1/η)/f_server of eq. (10) runs at
                    # rate f_server/K, i.e. K× longer — add the (K−1)×
                    # stretch on top of the dedicated-GPU pricing
                    srv = (1.0 - float(alloc.A)) * dm.compute_time(
                        exp.fcfg, net, eta, 0.0)
                    total = total + (K - 1) * srv
                cache[j] = total
            return cache[j]

        return durations, pricing

    # -- the timeline ------------------------------------------------------
    def planner(self, exp, *, campaign_seed, start, target, cohort,
                fixed_cohort, deadline, resample_channel, reallocate,
                realloc_search):
        K = exp.fcfg.num_clients
        if fixed_cohort is not None and fixed_cohort != K:
            raise ValueError(
                f"schedule {self.name!r} runs the full population (K={K}) "
                f"through every aggregation; batches= has leading axis "
                f"{fixed_cohort} — pass stream=/batches_fn= or K-sized "
                f"batches")
        # the population model (9th axis) may restrict the timeline to its
        # representative clients (meanfield): only those launch/complete,
        # so the event heap holds O(C) entries instead of O(K).  ``exact``
        # and ``compact`` return None — the full population runs.
        pop = getattr(exp, "population", None)
        active = pop.timeline_clients() if pop is not None else None
        members = (np.arange(K) if active is None
                   else np.asarray(active, int))
        if self.buffer_k > len(members):
            # the pending buffer is keyed by client (a recompletion
            # supersedes its own stale update), so it can never hold more
            # than len(members) distinct arrivals — the timeline would
            # spin forever
            raise ValueError(
                f"schedule {self.name!r} buffer_k={self.buffer_k} can never "
                f"fill with only {len(members)} timeline clients "
                f"(num_clients={K}; the buffer holds at most one pending "
                f"update per client)")
        durations, pricing = self._duration_table(exp, campaign_seed,
                                                  resample_channel,
                                                  reallocate, realloc_search)
        sim = EventSim()
        plans: dict[int, RoundPlan] = {}
        state = {"version": 0, "last_agg": 0.0, "round_events": [],
                 "since_agg": 0}
        start_version = np.zeros(K, int)
        run_idx = np.zeros(K, int)
        # pending updates keyed by client: a client that completes AGAIN
        # before the buffer fills supersedes its own stale pending update
        # (one round-function slot per client), so an aggregation always
        # carries ``buffer_k`` DISTINCT arrivals
        buffer: dict[int, int] = {}  # client -> staleness of pending update

        def launch(sim, k: int) -> None:
            d = float(durations(run_idx[k])[k])
            run_idx[k] += 1
            start_version[k] = state["version"]
            if deadline is not None and not d <= deadline:
                sim.after(float(deadline), "timeout", client=k)
            else:
                sim.after(d, "complete", client=k)

        def handler(sim, ev) -> None:
            k = ev.data.get("client")
            # stall guard: with every handler path relaunching the client,
            # the heap never drains — a deadline that cancels EVERY run
            # would otherwise spin timeouts until the generic event budget
            state["since_agg"] += 1
            if state["since_agg"] > 50 * K:
                raise RuntimeError(
                    f"schedule {self.name!r} produced no aggregation in "
                    f"{state['since_agg']} events (at round "
                    f"{state['version']} of {target}) — the deadline "
                    f"({deadline}) cancels every run before completion")
            if ev.kind == "timeout":
                state["round_events"].append(
                    {"t": ev.time, "kind": "timeout", "client": k})
                launch(sim, k)
                return
            if ev.kind != "complete":
                return
            r = state["version"]
            stale = r - start_version[k]
            state["round_events"].append(
                {"t": ev.time, "kind": "complete", "client": k,
                 "staleness": int(stale)})
            buffer[k] = int(stale)
            if len(buffer) >= self.buffer_k:
                mask = np.zeros(K, np.float32)
                staleness = np.zeros(K, float)
                scale = np.ones(K, float)
                for c, s in buffer.items():
                    mask[c] = 1.0
                    staleness[c] = s
                    scale[c] = float(federated.staleness_discount(s, self.beta))
                buffer.clear()
                state["round_events"].append(
                    {"t": ev.time, "kind": "aggregate", "round": r,
                     "arrivals": int(mask.sum())})
                arrived = mask > 0
                plans[r] = RoundPlan(
                    round=r, mask=mask,
                    round_time=float(ev.time - state["last_agg"]),
                    client_ids=np.arange(K), weight_scale=scale,
                    # server mixing rate α: the mean staleness discount of
                    # the buffered arrivals — the ABSOLUTE damping a
                    # normalized weighted mean cannot express (with one
                    # arrival any per-client discount cancels)
                    update_scale=float(np.mean(scale[arrived])),
                    staleness=staleness,
                    events=state["round_events"])
                state["last_agg"] = ev.time
                state["round_events"] = []
                state["since_agg"] = 0
                state["version"] = r + 1
                if state["version"] >= target:
                    sim.stop()
            launch(sim, k)

        for k in members:
            launch(sim, int(k))
        sim.run(handler, max_events=max(10_000, 1_000 * (target + 1) * K))
        return _TimelinePlanner(plans, pricing)


@schedules.register("semi-async")
class SemiAsyncSchedule(AsyncSchedule):
    """FedBuff-style buffered asynchrony: aggregate every ``buffer_k``
    arrivals instead of every single one.  Same timeline machinery, same
    staleness discount — the buffer trades aggregation frequency (server
    load, version churn) against per-update freshness."""

    name = "semi-async"
    buffer_k = 4


def get_schedule(spec: Union[str, Schedule]) -> Schedule:
    """Resolve a schedule name or pass an instance through.

    ``get_schedule("pipelined")`` → the registered default instance;
    ``get_schedule(PipelinedSchedule(num_microbatches=8))`` → the object
    itself.  Unknown names raise ``KeyError`` listing the registered names.
    """
    if isinstance(spec, Schedule):
        return spec
    if isinstance(spec, type) and issubclass(spec, Schedule):
        return spec()
    cls = schedules.get(spec)
    return cls()
