"""Shared-resource service models for the simulated system plane.

The §III/§IV delay model prices every compute step and every *wireless*
transfer in isolation, and the queued backhaul modes close the loop on the
edge→cloud leg: with ``backhaul_model="fifo" | "ps"`` the composed path
re-times through a SHARED metro queue (this module), and the allocator's
per-cell convex solves fold the matching *expected* wait back into their
latency budgets (``repro.net.allocation``'s wait-aware fixed point), so
contention is optimized against instead of assumed away.  The disciplines:

  * :func:`fifo` — a single-capacity first-come-first-served queue (the
    metro backhaul: one cell's burst delays the next cell's transfer);
  * :func:`processor_sharing` — egalitarian fluid sharing (a GPU or a
    statistically-multiplexed pipe: n concurrent jobs each progress at
    rate/n);
  * :func:`broadcast_seconds` — the downlink broadcast cost the paper
    treats as negligible: ONE multicast transmission per cell per round,
    every attached client pays the same wait.

All functions are pure numpy on host-side arrays — they plug into the
topology's per-hop delay composition (``repro/net/topology.py`` with
``backhaul_model="fifo" | "ps"``) and into the asynchronous execution
schedules (``repro.des.schedules``).  :func:`md1_mean_wait` and
:func:`ps_mean_wait` are the textbook M/D/1 and M/G/1-PS queueing formulas
the simulated disciplines are sanity-checked against in ``tests/test_des.py``
— and the analytic expected-wait terms the wait-aware allocator prices with.
"""

from __future__ import annotations

import numpy as np


def service_seconds(bits, capacity_bps: float) -> np.ndarray:
    """Transfer time of each job on a ``capacity_bps`` link (inf at cap 0)."""
    bits = np.asarray(bits, float)
    if capacity_bps <= 0:
        return np.full_like(bits, np.inf)
    return bits / float(capacity_bps)


def fifo(arrivals, service) -> tuple[np.ndarray, np.ndarray]:
    """Single-server FIFO queue: ``(completion, wait)`` per job.

    Jobs are served in arrival order (ties broken by index — the same
    ``(time, seq)`` discipline as the event engine): job i starts at
    ``max(arrival_i, completion_of_previous)``.  ``wait`` is the queueing
    delay only (start − arrival), so ``completion = arrival + wait +
    service``.  Arrays come back in the ORIGINAL job order.

    Jobs with a non-finite arrival never reach the queue (an outage'd
    client whose wireless total is +inf): their completion and wait are
    +inf and they occupy no server time.
    """
    arrivals = np.asarray(arrivals, float)
    service = np.broadcast_to(np.asarray(service, float), arrivals.shape)
    order = np.argsort(arrivals, kind="stable")
    completion = np.full_like(arrivals, np.inf)
    wait = np.full_like(arrivals, np.inf)
    free_at = 0.0
    for i in order:
        if not np.isfinite(arrivals[i]):
            continue  # never arrives; +inf completion already set
        start = max(arrivals[i], free_at)
        wait[i] = start - arrivals[i]
        free_at = start + service[i]
        completion[i] = free_at
    return completion, wait


def processor_sharing(arrivals, demands, rate: float = 1.0) -> np.ndarray:
    """Egalitarian processor sharing: completion time per job.

    ``demands`` are in resource-seconds (or bits with ``rate`` in bits/s):
    while n jobs are in the system each progresses at ``rate / n``.  Solved
    exactly by fluid event stepping between arrivals/departures — at every
    step the job with the least remaining demand fixes the step length.
    Deterministic in its inputs (ties resolve by job index).  Jobs with a
    non-finite arrival never enter the system (completion +inf).
    """
    arrivals = np.asarray(arrivals, float)
    remaining = np.broadcast_to(np.asarray(demands, float),
                                arrivals.shape).copy()
    n = len(arrivals)
    completion = np.full(n, np.inf)
    if rate <= 0 or n == 0:
        return completion
    if not np.all(np.isfinite(arrivals)):
        finite = np.isfinite(arrivals)
        completion[finite] = processor_sharing(arrivals[finite],
                                               remaining[finite], rate)
        return completion
    # completion tolerance relative to the workload scale: a residue this
    # small cannot advance the clock by a representable step
    tol = 1e-9 * max(float(np.max(remaining)), 1e-300)
    order = np.argsort(arrivals, kind="stable")
    active: list[int] = []
    now = 0.0
    next_arrival = 0
    while next_arrival < n or active:
        if not active:  # idle until the next arrival
            now = arrivals[order[next_arrival]]
        # admit everything that has arrived by `now`
        while next_arrival < n and arrivals[order[next_arrival]] <= now:
            active.append(order[next_arrival])
            next_arrival += 1
        share = rate / len(active)
        # step to the earlier of: next arrival, first in-service completion
        first_done = min(active, key=lambda i: (remaining[i], i))
        t_done = now + remaining[first_done] / share
        t_next = arrivals[order[next_arrival]] if next_arrival < n else np.inf
        if t_next < t_done:
            drained = share * (t_next - now)
            now = t_next
        else:
            drained = share * (t_done - now)
            now = t_done
        for i in active:
            remaining[i] -= drained
        if t_next >= t_done:
            # we stepped exactly to first_done's finish — complete it
            # regardless of rounding residue (guards against a clock stall
            # when residue/share underflows below one ulp of `now`)
            remaining[first_done] = 0.0
        for i in [i for i in active if remaining[i] <= tol]:
            completion[i] = now
            active.remove(i)
    return completion


def broadcast_seconds(bits: float, capacity_bps: float) -> float:
    """Downlink broadcast: ONE multicast transmission serves every receiver.

    Unlike the uplink (per-client FDMA shares), the broadcast of the global
    model rides a single downlink transmission per cell — the cost is
    ``bits / capacity`` once, not per client.  ``capacity_bps <= 0`` means
    the term is disabled (the paper's negligible-downlink convention) and
    costs 0.
    """
    if capacity_bps <= 0:
        return 0.0
    return float(bits) / float(capacity_bps)


def md1_mean_wait(arrival_rate: float, service_s: float) -> float:
    """Analytic M/D/1 mean queueing wait  W_q = ρ·s / (2·(1−ρ)).

    Poisson arrivals at ``arrival_rate`` into a single FIFO server with
    DETERMINISTIC service time ``service_s`` (utilisation ρ = λ·s < 1).
    The reference the simulated FIFO backhaul is checked against at low
    utilisation (Pollaczek–Khinchine with zero service variance).
    """
    rho = arrival_rate * service_s
    if rho >= 1.0:
        return np.inf
    return rho * service_s / (2.0 * (1.0 - rho))


def ps_mean_wait(arrival_rate: float, service_s: float) -> float:
    """Analytic M/D/1-PS mean *extra* delay  W = ρ·s / (1−ρ).

    Poisson arrivals at ``arrival_rate`` into a single egalitarian
    processor-sharing server with service requirement ``service_s``
    (utilisation ρ = λ·s < 1).  M/G/1-PS mean sojourn is the insensitive
    s/(1−ρ) — independent of the service distribution, so it holds exactly
    for the deterministic demands the backhaul carries — and the *wait*
    (sojourn minus the job's own service) is ρ·s/(1−ρ).  The reference the
    simulated PS discipline is checked against at low utilisation, and the
    PS branch of the wait-aware allocator's expected-wait term.
    """
    rho = arrival_rate * service_s
    if rho >= 1.0:
        return np.inf
    return rho * service_s / (1.0 - rho)
