"""Deterministic discrete-event simulation engine.

A minimal event-heap simulator for the host-side wireless/system timeline:
events are ``(time, seq, kind, data)`` tuples popped in ``(time, seq)``
order, where ``seq`` is the monotonically increasing scheduling counter —
so simultaneous events fire in the exact order they were scheduled and a
run is a *pure function of its inputs*: the engine owns no RNG, reads no
clock, and two runs fed identical schedules produce identical traces.
That is the property the campaign engine's bit-reproducibility contract
(``tests/test_campaign.py``) needs from an asynchronous timeline: every
execution schedule (``repro.des.schedules``) replays exactly under
checkpoint resume because its event order is a function of
``(RunConfig, seed)``, never of host timing.

    sim = EventSim()
    for k, t in enumerate(completion_times):
        sim.schedule(t, "complete", client=k)
    trace = sim.run(on_event)     # handler may sim.schedule(...) more

Everything is host-side and stdlib-only; nothing here touches jax.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class Event:
    """One scheduled occurrence.  Ordered by ``(time, seq)`` — ``seq`` is
    assigned at scheduling time, so ties in simulated time resolve in
    scheduling order (deterministically), never by payload comparison."""

    time: float
    seq: int
    kind: str = field(compare=False)
    data: dict = field(compare=False, default_factory=dict)

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventSim:
    """Pure event-heap simulator.

    ``schedule`` enqueues an event at an absolute simulated time (which may
    equal, but never precede, the current time while running); ``run`` pops
    events in ``(time, seq)`` order, advances ``now``, appends each popped
    event to ``trace`` and hands it to the handler — which may schedule
    further events.  ``run`` returns the trace (the per-event timing record
    the campaign attaches to its round records).
    """

    def __init__(self):
        self.now = 0.0
        self.trace: list[Event] = []
        self._heap: list[Event] = []
        self._seq = 0
        self._stopped = False

    def stop(self) -> None:
        """Ask ``run`` to return after the current event (handlers call this
        when their termination condition — e.g. enough aggregations — is
        met; queued events stay queued)."""
        self._stopped = True

    def schedule(self, time: float, kind: str, **data) -> Event:
        """Enqueue ``kind`` at absolute simulated ``time``."""
        if time < self.now:
            raise ValueError(
                f"cannot schedule {kind!r} at t={time} in the past "
                f"(now={self.now})")
        ev = Event(float(time), self._seq, kind, data)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, delay: float, kind: str, **data) -> Event:
        """Enqueue ``kind`` at ``now + delay``."""
        if delay < 0:
            raise ValueError(f"negative delay {delay} for {kind!r}")
        return self.schedule(self.now + float(delay), kind, **data)

    def run(self, handler: Optional[Callable[["EventSim", Event], None]] = None,
            until: Optional[float] = None,
            max_events: int = 1_000_000) -> list[Event]:
        """Drain the heap in ``(time, seq)`` order.

        ``handler(sim, event)`` runs per popped event and may schedule more;
        ``until`` stops the clock (events strictly later stay queued);
        ``max_events`` guards against a handler that schedules forever.
        Returns ``self.trace`` (all events popped so far, in order).
        """
        popped = 0
        self._stopped = False
        while self._heap and not self._stopped:
            if until is not None and self._heap[0].time > until:
                break
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            self.trace.append(ev)
            if handler is not None:
                handler(self, ev)
            popped += 1
            if popped >= max_events:
                raise RuntimeError(
                    f"event budget exhausted ({max_events}) — a handler is "
                    f"likely scheduling unconditionally")
        return self.trace

    @property
    def pending(self) -> int:
        """Events still queued (not yet popped by ``run``)."""
        return len(self._heap)
