"""Event-driven execution: discrete-event simulator, queueing, schedules.

``engine`` is the deterministic event-heap simulator (events popped in
``(time, seq)`` order — a run is a pure function of its inputs);
``queueing`` adds shared-resource service models (FIFO / processor-sharing
backhaul and GPU, downlink broadcast cost, the M/D/1 reference formula);
``schedules`` exposes the execution discipline as the 6th name registry —
``sync`` (the bit-identical round-synchronous default) | ``pipelined``
(microbatch overlap across the wireless split) | ``async`` (immediate
rejoin + staleness-weighted aggregation) | ``semi-async`` (FedBuff
buffer-K).
"""

from repro.des import queueing
from repro.des.engine import Event, EventSim
from repro.des.schedules import (RoundPlan, Schedule, get_schedule,
                                 schedules)

__all__ = ["Event", "EventSim", "queueing",
           "RoundPlan", "Schedule", "get_schedule", "schedules"]
