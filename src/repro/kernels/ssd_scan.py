"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

State-space duality layout: per (batch, head) the sequence is processed in
chunks of Q; the quadratic intra-chunk term and the state in/out projections
are MXU matmuls; the (N, P) recurrent state lives in fp32 VMEM scratch and
persists across the (sequential, innermost) chunk grid dimension:

  y[c]    = tril(C_c·B_cᵀ ⊙ decay) · (dt·x)_c  +  (C_c ⊙ decay_in) · h_{c-1}
  h_c     = exp(Σ log a_c) · h_{c-1}  +  B_cᵀ · (decay_out ⊙ (dt·x)_c)

This is the TPU adaptation of the Mamba-2 GPU kernel: instead of warp-level
scans, the inter-chunk recurrence is carried in VMEM between grid steps (the
TPU grid is sequential), and all O(Q²)/O(Q·N·P) work is shaped for the MXU.

Grid = (B, H, S/Q); chunks innermost.  x (B,S,H,P), dt (B,S,H) pre-scaled
outside, A (H,), Bm/Cm (B,S,N) shared across heads (groups = 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, Q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    la = dt * A  # (Q,) log decay per step
    cs = jnp.cumsum(la)  # (Q,)
    xw = x * dt[:, None]  # dt-weighted input

    # intra-chunk: scores[q, s] = (C_q·B_s) · exp(cs_q - cs_s) for s <= q
    seg = cs[:, None] - cs[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot(scores, xw, preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: contribution of the carried state
    decay_in = jnp.exp(cs)[:, None]  # decay from chunk start to step q
    y += jax.lax.dot(Cm * decay_in, state_ref[...],
                     preferred_element_type=jnp.float32)  # (Q,N)x(N,P)

    # state update: h = exp(sum la)·h + Bᵀ·(decay_to_end ⊙ xw)
    total = cs[-1]
    decay_out = jnp.exp(total - cs)[:, None]  # (Q, 1)
    state_ref[...] = jnp.exp(total) * state_ref[...] + jax.lax.dot_general(
        Bm, xw * decay_out, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (N, Q)x(Q, P) -> (N, P)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N) -> y (B,S,H,P)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    grid = (B, H, nc)
    return pl.pallas_call(
        functools.partial(_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
