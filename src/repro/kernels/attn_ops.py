"""Jit'd public wrapper for flash attention (padding + interpret fallback)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.attn_ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """q (B,H,S,d), k/v (B,Kv,S,d). Pads seq to block multiples."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, Sq, d = q.shape
    Skv = k.shape[2]
    bq_ = min(bq, max(8, Sq))
    bk_ = min(bk, max(8, Skv))
    Sqp = ((Sq + bq_ - 1) // bq_) * bq_
    Skp = ((Skv + bk_ - 1) // bk_) * bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Skv), (0, 0)))
    if Skp > Skv and not causal:
        # padded kv must be masked; causal masks them iff Sqp==Skp alignment —
        # handle by masking keys beyond Skv via a window-free causal trick:
        # simplest correct route: fall back to reference for ragged non-causal
        return flash_attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    o = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                               softcap=softcap, bq=bq_, bk=bk_, interpret=interpret)
    return o[:, :, :Sq, :]


__all__ = ["flash_attention", "flash_attention_ref"]
