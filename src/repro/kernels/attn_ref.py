"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: (B,H,Sq,d); k/v: (B,Kv,Skv,d)."""
    B, H, Sq, d = q.shape
    Kv, Skv = k.shape[1], k.shape[2]
    rep = H // Kv
    kk = jnp.repeat(k, rep, axis=1)
    vv = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / math.sqrt(d)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
