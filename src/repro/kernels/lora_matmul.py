"""Fused LoRA matmul Pallas TPU kernel.

Computes  y = x·W + scale·(x·A)·B  in a single VMEM pass over x:

  * x tile (bm, bk) is read from HBM once and feeds BOTH the frozen-weight
    matmul (MXU, bk×bn tiles of W) and the low-rank path (bk×r tile of A);
    the naive two-op formulation reads x twice and round-trips the (M, r)
    intermediate through HBM.
  * The rank-r intermediate u = x·A accumulates in a (bm, r) fp32 VMEM
    scratch across the K loop; on the last K step it is folded into the
    accumulator via u·B (r ≤ 128, so the fold is a single MXU pass).
  * Default block sizes are MXU-aligned (128, 128, 512).

Grid = (M/bm, N/bn, K/bk), K innermost (sequential on TPU — VMEM scratch
accumulators persist across K steps and are reset at k == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, u_ref, *, scale: float, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...]
    # frozen-weight path (MXU)
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # low-rank path: accumulate u = x·A (bm, r)
    u_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _fold():
        u = u_ref[...]
        delta = jnp.dot(u, b_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * delta).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk", "interpret"))
def lora_matmul_pallas(x, w, a, b, *, scale: float = 1.0, bm: int = 128,
                       bn: int = 128, bk: int = 512, interpret: bool = False):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N) -> (M, N)."""
    M, K = x.shape
    K2, N = w.shape
    r = a.shape[1]
    assert K == K2 == a.shape[0] and b.shape == (r, N)
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk

    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk, r), lambda m, n, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
