"""Flash attention Pallas TPU kernel (online softmax, causal / sliding-window
/ logit-softcap / GQA).

Layout: q (B, H, Sq, d), k/v (B, Kv, Skv, d), GQA via head-index mapping in
the k/v BlockSpecs (no materialised repeat).  Grid = (B, H, Sq/bq, Skv/bk),
kv innermost; running max/denominator/accumulator live in fp32 VMEM scratch
and persist across the kv loop.  Fully-masked kv blocks (beyond the causal
frontier or outside the sliding window) skip their compute via ``pl.when``.

This is the TPU adaptation of the memory-bound attention hot spot: logits
never round-trip to HBM (the jnp path materialises (bq, Skv) per row block),
and tiles are MXU-aligned.  VMEM working set ≈ bq·d + 2·bk·d + bq·bk floats.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # block-level relevance (skip fully-masked blocks)
    needed = True
    if causal:
        needed = k_start <= q_start + bq - 1
    if window > 0:
        needed = jnp.logical_and(needed, k_start + bk - 1 > q_start - window)

    @pl.when(needed if not isinstance(needed, bool) else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (B, H, Sq, d); k/v: (B, Kv, Skv, d); returns (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    _, Kv, Skv, _ = k.shape
    assert H % Kv == 0
    rep = H // Kv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(d)

    grid = (B, H, nq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          softcap=softcap, bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
