"""Pure-jnp oracle for the SSD scan kernel: the exact sequential recurrence
h_t = exp(dt_t·A)·h_{t-1} + dt_t·(B_t ⊗ x_t),  y_t = C_t·h_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,N), (B,N)
        a_t = jnp.exp(dt_t * Af[None, :])  # (B,H)
        upd = jnp.einsum("bn,bhp->bhpn", B_t, x_t * dt_t[..., None])
        h = h * a_t[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y_t

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,H,P)
