"""Pure-jnp oracle for the fused LoRA matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, *, scale: float = 1.0):
    """y = x·W + scale·(x·A)·B, fp32 accumulation, cast to x.dtype."""
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    u = jnp.dot(x, a, preferred_element_type=jnp.float32)
    delta = jnp.dot(u, b.astype(jnp.float32), preferred_element_type=jnp.float32)
    return (base + scale * delta).astype(x.dtype)
