"""Jit'd public wrapper for the fused LoRA matmul.

Handles arbitrary leading batch dims, non-aligned shapes (zero padding to
block multiples), dtype promotion, and the CPU fallback (interpret mode when
no TPU is attached — used by tests; on TPU the compiled kernel runs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lora_matmul import lora_matmul_pallas
from repro.kernels.lora_ref import lora_matmul_ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def lora_matmul(x, w, a, b, *, scale: float = 1.0, bm: int = 128, bn: int = 128,
                bk: int = 512, interpret: bool | None = None):
    """y = x·W + scale·(x·A)·B with x (..., K), w (K, N), a (K, r), b (r, N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    r = a.shape[1]
    M = 1
    for s in lead:
        M *= s
    x2 = x.reshape(M, K)

    bm_ = min(bm, _round_up(M, 8))
    bn_ = min(bn, _round_up(N, 128))
    bk_ = min(bk, _round_up(K, 128))
    Mp, Np, Kp = _round_up(M, bm_), _round_up(N, bn_), _round_up(K, bk_)
    rp = _round_up(r, 8)
    xp = jnp.pad(x2, ((0, Mp - M), (0, Kp - K)))
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
    ap = jnp.pad(a, ((0, Kp - K), (0, rp - r)))
    bp = jnp.pad(b, ((0, rp - r), (0, Np - N)))
    y = lora_matmul_pallas(xp, wp, ap, bp, scale=scale, bm=bm_, bn=bn_, bk=bk_,
                           interpret=interpret)
    return y[:M, :N].reshape(*lead, N)


__all__ = ["lora_matmul", "lora_matmul_ref"]
