"""Jit'd public wrapper for the SSD scan kernel."""

from __future__ import annotations

import jax

from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.ssd_ref import ssd_scan_ref


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


__all__ = ["ssd_scan", "ssd_scan_ref"]
