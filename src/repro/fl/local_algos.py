"""Local-update algorithms — the 7th pluggable strategy axis (``local_algos``).

The paper's Algorithm 1 fixes the client step to plain gradient descent on
problem (4).  Under IID synthetic tokens that is also the *right* step — but
the heterogeneous regimes the other six axes exist for (FedLLM-Bench-style
quantity/length/domain skew; see :mod:`repro.fl.workloads`) introduce client
drift, and the federated-optimization literature's standard correctives for
drift are drop-in modifications of exactly that inner step:

  ``gd``        the paper's plain GD on problem (4) — the default, and
                bit-identical to the pre-registry trajectories (tests pin
                this: ``correct`` is the identity, so the jaxpr is unchanged)
  ``fedprox``   FedProx (Li et al., MLSys'20): adds the proximal term
                (μ/2)‖h‖² against the broadcast global LoRA state, i.e. the
                corrected gradient is ∇G + μ·h.  Since ``h`` *is* the local
                deviation from the broadcast (Δw + h), no extra round-state
                is needed; μ = 0 recovers ``gd`` exactly.
  ``scaffold``  SCAFFOLD (Karimireddy et al., ICML'20) option II: every local
                step is corrected by control variates, ∇G − c_k + c̄, and the
                per-client variates c_k are updated after the round's I_loc
                steps as c_k⁺ = c_k − c̄ − h/(I_loc·δ) (the client's mean
                corrected gradient).  The (K, …) variates are *round-function
                state*: they ride through the jitted round as value-only
                arguments (like mask/weights/assign), are carried across
                campaign rounds on the Experiment, and are checkpointed.

An algorithm decides two things inside the jitted round: how the
problem-(4) gradient is transformed before the δ step (:meth:`correct`) and
— when ``stateful`` — how its per-client variates evolve after the local
scan (:meth:`update_variates`).  Both are pure pytree maps, so every
algorithm keeps the single-trace-per-η-bucket contract (``trace_count``
bounds are asserted in ``tests/test_fl.py`` like they are for masks).

    exp = Experiment.from_config(run_cfg, local_algo="scaffold",
                                 workload="dirichlet")

Unknown names raise ``KeyError`` listing the knowns, like every registry.
"""

from __future__ import annotations

from typing import Any, Optional, Union

import jax
import jax.numpy as jnp

from repro.registry import Registry

local_algos: Registry = Registry("local_algo")


class LocalAlgo:
    """Strategy protocol for the client's local-update rule.

    ``correct(g, h, ctrl, ctrl_bar)`` transforms the problem-(4) gradient
    ``g`` (a ``(h_c, h_s)``-shaped pytree) before the ``h ← h − δ·g`` step;
    ``h`` is the current local deviation, ``ctrl``/``ctrl_bar`` the client's
    control variate and the population mean (both None for stateless
    algorithms).  It runs *inside* the jitted scan body, so it must be a
    pure jnp/pytree computation.

    ``stateful`` algorithms additionally carry per-client variates: a
    ``(K, …)``-stacked pytree shaped like the LoRA adapters, initialised by
    :meth:`init_variates` and advanced once per round by
    :meth:`update_variates` (masked clients must keep their old variates —
    a straggler that missed the round learned nothing).

    ``params()`` feeds the campaign checkpoint identity (resume refuses a
    checkpoint written under a different algorithm or hyper-parameters),
    exactly like ``Schedule.params()``.
    """

    name = "base"
    stateful = False

    def params(self) -> dict:
        return {}

    def correct(self, g, h, ctrl, ctrl_bar):
        """Transformed gradient for the ``h ← h − δ·(·)`` local step."""
        return g

    def init_variates(self, template, num_clients: int):
        """Fresh per-client variates: ``(num_clients, …)`` stacked like
        ``template`` (the global LoRA pytree), or None when stateless."""
        return None

    def update_variates(self, variates, ctrl_bar, h, mask, I_loc: int,
                        delta: float):
        """Post-round variate update on the cohort slice (value-only)."""
        return variates

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        kv = ", ".join(f"{k}={v!r}" for k, v in sorted(self.params().items()))
        return f"{type(self).__name__}({kv})"


@local_algos.register("gd")
class GDLocal(LocalAlgo):
    """The paper's plain gradient descent on problem (4) (eq. 9)."""

    name = "gd"


@local_algos.register("fedprox")
class FedProxLocal(LocalAlgo):
    """FedProx: proximal term (μ/2)‖h‖² against the broadcast global state.

    The local objective becomes G_k(h) + (μ/2)‖h‖², so the corrected
    gradient is ∇G + μ·h — ``h`` is already the deviation from the broadcast
    Δw, so the proximal pull needs no extra round-function argument.
    """

    name = "fedprox"

    def __init__(self, mu: float = 0.1):
        self.mu = float(mu)

    def params(self) -> dict:
        return {"mu": self.mu}

    def correct(self, g, h, ctrl, ctrl_bar):
        return jax.tree.map(lambda gx, hx: gx + self.mu * hx, g, h)


@local_algos.register("scaffold")
class ScaffoldLocal(LocalAlgo):
    """SCAFFOLD: control-variate-corrected local steps (option II).

    Local step:   h ← h − δ·(∇G(h) − c_k + c̄)
    After I_loc steps (option II, with the local lr δ):
                  c_k⁺ = c_k − c̄ − h/(I_loc·δ)
    The server-side c̄ is the mean of the *stored* variates over all K
    simulated users — equivalent to SCAFFOLD's running server rule
    c ← c + (|S|/K)·mean_S(Δc_k) because dropped clients keep c_k
    unchanged.  Variates start at zero, so round 0 is bit-identical to
    ``gd`` and corrections only appear once clients have drifted apart.
    """

    name = "scaffold"
    stateful = True

    def init_variates(self, template, num_clients: int):
        return jax.tree.map(
            lambda x: jnp.zeros((num_clients,) + x.shape, x.dtype), template)

    def correct(self, g, h, ctrl, ctrl_bar):
        return jax.tree.map(lambda gx, ck, cb: gx - ck + cb, g, ctrl, ctrl_bar)

    def update_variates(self, variates, ctrl_bar, h, mask, I_loc: int,
                        delta: float):
        inv = 1.0 / (float(I_loc) * float(delta))
        new = jax.tree.map(lambda ck, cb, hk: ck - cb[None] - inv * hk,
                           variates, ctrl_bar, h)
        if mask is None:
            return new
        # stragglers keep their old variates: new = m·upd + (1−m)·old
        def blend(old, upd):
            m = jnp.reshape(mask, (-1,) + (1,) * (upd.ndim - 1)).astype(upd.dtype)
            return m * upd + (1.0 - m) * old

        return jax.tree.map(blend, variates, new)


def get_local_algo(spec: Union[str, LocalAlgo, type], **kw) -> LocalAlgo:
    """Resolve a local-algorithm name / class / instance.

    ``get_local_algo("fedprox", mu=0.3)`` → a configured instance;
    ``get_local_algo(ScaffoldLocal())`` → the object itself.  Unknown names
    raise ``KeyError`` listing the registered names.
    """
    if isinstance(spec, LocalAlgo):
        if kw:
            raise TypeError("pass kwargs with a name, not an instance")
        return spec
    if isinstance(spec, type) and issubclass(spec, LocalAlgo):
        return spec(**kw)
    cls = local_algos.get(spec)
    return cls(**kw)
