"""Federated-learning strategy axes beyond the paper's Algorithm 1.

``repro.fl`` holds the two client-side axes the FedsLLM paper fixes but
heterogeneous deployments vary: the *local-update algorithm* (the 7th name
registry — ``gd`` / ``fedprox`` / ``scaffold``) and the *data workload*
(``iid`` / ``quantity-skew`` / ``length-skew`` / ``dirichlet``).  Both plug
into :class:`repro.api.Experiment` by name::

    exp = Experiment.from_config(run_cfg, local_algo="fedprox",
                                 workload="dirichlet")
"""

from repro.fl.local_algos import (LocalAlgo, get_local_algo,  # noqa: F401
                                  local_algos)
from repro.fl.workloads import (Workload, get_workload,  # noqa: F401
                                workloads)
