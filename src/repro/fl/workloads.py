"""Data-heterogeneity workloads — first-class non-IID client data.

Every campaign so far fed clients IID slices of one synthetic
:class:`~repro.data.tokens.TokenStream`, so the local-update algorithms in
:mod:`repro.fl.local_algos` (and the aggregators/schedules above them)
could never disagree — there is no client drift to correct.  A *workload*
decides what data each simulated client actually sees, in the three
heterogeneity modes FedLLM-Bench-style splits measure on real federated
LLM corpora:

  ``iid``            each client reads its own fresh positions of the
                     stream — bit-identical to the legacy
                     ``campaign.stream_batcher`` (tests pin this)
  ``quantity-skew``  Dirichlet(α) quantity split: client k owns a finite
                     pool of n_k batches (n_k ∝ a Dirichlet draw) and
                     cycles it, so small-pool clients revisit the same few
                     batches every round (quantity/participation skew)
  ``length-skew``    per-client sequence budget: client k's loss mask is
                     truncated to a fixed fraction of the sequence, so
                     clients train on systematically different effective
                     lengths (FedLLM-Bench's length diversity)
  ``dirichlet``      domain skew: a pool of ``num_domains`` distinct
                     synthetic domains (different bigram ``structure``
                     levels and seeds) is Dirichlet-partitioned across
                     clients via :func:`repro.data.partition
                     .dirichlet_partition`, so each client's token
                     distribution is dominated by its own domains

A workload is *pure in (stream.seed, client, round)*: client k's batch at
round r never depends on who else was sampled into the cohort, so elastic
cohorts, straggler masks and checkpoint resume stay bit-reproducible
(property-tested in ``tests/test_fl.py``).  ``batcher(stream, K)`` returns
the same ``fn(round_idx, client_ids) -> stacked pytree`` contract the
campaign engine's data sources use; ``params()`` feeds the campaign
checkpoint identity like schedule/local-algo params do.

Unknown names raise ``KeyError`` listing the knowns, like every registry.
"""

from __future__ import annotations

from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import dirichlet_partition
from repro.registry import Registry

workloads: Registry = Registry("workload")

# seed offsets separating this module's host-side RNG draws from every other
# consumer of the stream seed (cohorts, channels, DP all use other streams)
_QUANTITY_TAG = 0x51AD
_LENGTH_TAG = 0x1E57
_DOMAIN_TAG = 0xD0
_DOMAIN_SEED_STRIDE = 9973


def _stack(per_client: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_client)


class Workload:
    """Strategy protocol for the per-client data distribution."""

    name = "base"

    def params(self) -> dict:
        return {}

    def batcher(self, stream, num_clients: int) -> Callable[[int, np.ndarray], Any]:
        """``fn(round_idx, client_ids) -> (C, ...)``-stacked pytree."""
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        kv = ", ".join(f"{k}={v!r}" for k, v in sorted(self.params().items()))
        return f"{type(self).__name__}({kv})"


@workloads.register("iid")
class IIDWorkload(Workload):
    """Fresh IID positions — client k reads ``r·K + k`` of the stream.

    Bit-identical to the legacy ``campaign.stream_batcher`` path (and to
    ``data.tokens.client_batches`` when the cohort is the full population).
    """

    name = "iid"

    def batcher(self, stream, num_clients: int):
        def fn(round_idx: int, client_ids: np.ndarray):
            return _stack([stream.batch_at(round_idx * num_clients + int(k))
                           for k in client_ids])

        return fn


@workloads.register("quantity-skew")
class QuantitySkewWorkload(Workload):
    """Dirichlet(α) quantity split over finite per-client batch pools.

    Client k owns ``n_k`` distinct stream positions, where the pool sizes
    follow a Dirichlet(α) draw over a total budget of ``pool_rounds`` rounds
    of data per client on average; at round r it serves position
    ``(r mod n_k)·K + k``.  Large α ⇒ near-equal pools; small α ⇒ a few
    data-rich clients and many clients grinding the same handful of batches
    (the drift regime FedProx's proximal term targets).
    """

    name = "quantity-skew"

    def __init__(self, alpha: float = 0.5, pool_rounds: int = 16):
        self.alpha = float(alpha)
        self.pool_rounds = int(pool_rounds)

    def params(self) -> dict:
        return {"alpha": self.alpha, "pool_rounds": self.pool_rounds}

    def pool_sizes(self, seed: int, num_clients: int) -> np.ndarray:
        rng = np.random.default_rng(seed + _QUANTITY_TAG)
        props = rng.dirichlet([self.alpha] * num_clients)
        total = self.pool_rounds * num_clients
        return np.maximum(1, np.round(props * total).astype(int))

    def batcher(self, stream, num_clients: int):
        sizes = self.pool_sizes(stream.seed, num_clients)

        def fn(round_idx: int, client_ids: np.ndarray):
            return _stack([
                stream.batch_at((round_idx % int(sizes[int(k)])) * num_clients
                                + int(k))
                for k in client_ids])

        return fn


@workloads.register("length-skew")
class LengthSkewWorkload(Workload):
    """Per-client sequence-length budgets via the loss mask.

    Client k trains every round on the leading ``L_k = max(1, ⌈f_k·S⌉)``
    tokens of its IID batch — ``f_k`` drawn once per population from
    Uniform[min_frac, 1] — by zeroing the loss mask past ``L_k``.  Token
    content stays the IID stream (the masked mean keeps loss scales
    comparable); what differs across clients is which context lengths their
    gradients ever see.
    """

    name = "length-skew"

    def __init__(self, min_frac: float = 0.25):
        if not 0.0 < min_frac <= 1.0:
            raise ValueError(f"min_frac={min_frac} must be in (0, 1]")
        self.min_frac = float(min_frac)

    def params(self) -> dict:
        return {"min_frac": self.min_frac}

    def length_fracs(self, seed: int, num_clients: int) -> np.ndarray:
        rng = np.random.default_rng(seed + _LENGTH_TAG)
        return rng.uniform(self.min_frac, 1.0, size=num_clients)

    def batcher(self, stream, num_clients: int):
        fracs = self.length_fracs(stream.seed, num_clients)
        lengths = np.maximum(1, np.ceil(fracs * stream.seq)).astype(int)
        pos = np.arange(stream.seq)

        def fn(round_idx: int, client_ids: np.ndarray):
            per_client = []
            for k in client_ids:
                b = dict(stream.batch_at(round_idx * num_clients + int(k)))
                keep = jnp.asarray(pos < lengths[int(k)], jnp.float32)
                b["mask"] = b["mask"] * keep[None, :]
                per_client.append(b)
            return _stack(per_client)

        return fn


@workloads.register("dirichlet")
class DirichletDomainWorkload(Workload):
    """Dirichlet(α) domain skew over distinct synthetic domains.

    A pool of ``num_domains × domain_pool`` shards — shard s lives in
    domain ``s // domain_pool``, each domain a :class:`TokenStream` with its
    own seed and its own bigram ``structure`` level (genuinely different
    token distributions, not just different draws) — is label-partitioned
    across clients with :func:`repro.data.partition.dirichlet_partition`.
    Each client cycles its own shard list across rounds, so small α gives
    clients dominated by one domain (the drift regime SCAFFOLD's control
    variates target) and large α recovers a near-uniform mixture.
    """

    name = "dirichlet"

    def __init__(self, alpha: float = 0.5, num_domains: int = 4,
                 domain_pool: int = 32):
        self.alpha = float(alpha)
        self.num_domains = int(num_domains)
        self.domain_pool = int(domain_pool)

    def params(self) -> dict:
        return {"alpha": self.alpha, "num_domains": self.num_domains,
                "domain_pool": self.domain_pool}

    def client_shards(self, seed: int, num_clients: int) -> list[np.ndarray]:
        total = self.num_domains * self.domain_pool
        if total < num_clients:
            raise ValueError(
                f"num_domains·domain_pool = {total} shards cannot cover "
                f"{num_clients} clients at min_size=1")
        labels = np.repeat(np.arange(self.num_domains), self.domain_pool)
        return dirichlet_partition(labels, num_clients, alpha=self.alpha,
                                   seed=seed + _DOMAIN_TAG, min_size=1)

    def domain_streams(self, stream) -> list:
        # distinct structure levels ⇒ distinct bigram determinism per domain
        levels = np.linspace(0.55, 0.95, self.num_domains)
        return [type(stream)(stream.batch, stream.seq, stream.vocab,
                             seed=stream.seed + _DOMAIN_SEED_STRIDE * (d + 1),
                             structure=float(levels[d]))
                for d in range(self.num_domains)]

    def batcher(self, stream, num_clients: int):
        shards = self.client_shards(stream.seed, num_clients)
        streams = self.domain_streams(stream)

        def fn(round_idx: int, client_ids: np.ndarray):
            per_client = []
            for k in client_ids:
                own = shards[int(k)]
                s = int(own[round_idx % len(own)])
                d, p = divmod(s, self.domain_pool)
                per_client.append(streams[d].batch_at(p))
            return _stack(per_client)

        return fn


def get_workload(spec: Union[str, Workload, type], **kw) -> Workload:
    """Resolve a workload name / class / instance (KeyError lists knowns)."""
    if isinstance(spec, Workload):
        if kw:
            raise TypeError("pass kwargs with a name, not an instance")
        return spec
    if isinstance(spec, type) and issubclass(spec, Workload):
        return spec(**kw)
    cls = workloads.get(spec)
    return cls(**kw)
