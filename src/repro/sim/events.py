"""Per-round scenario events for multi-round campaigns.

A training campaign is not one frozen channel draw: the §IV wireless network
changes between global rounds (block fading — coherence ≫ one round, ≪ the
campaign), cohorts are subsampled from the simulated user population, and
clients whose realised delay exceeds the round deadline become stragglers.
This module generates those per-round events deterministically from a
campaign seed + round index, so a campaign is a pure function of
``(RunConfig, seed)`` and resume/replay is bit-identical.

Everything here is host-side numpy (it drives the simulator, not the jitted
round function): only the resulting survivor ``mask`` crosses into device
compute, through the round function's existing ``mask`` argument.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.core import federated
from repro.core.resource_alloc import Allocation, quantize_eta

# Mixing stride between the campaign seed and the round index (same prime
# idiom as ``federated.client_sample`` — distinct streams per round without
# collisions across nearby campaign seeds).
ROUND_SEED_STRIDE = 1_000_003
# Tag added to the campaign seed for cohort sampling.  ``client_sample``
# mixes with the same prime as ``round_seed``, so an untagged seed would
# give cohort selection the byte-identical PRNG stream as that round's
# channel draw — correlating who trains with how the channel faded.
COHORT_STREAM_TAG = 0x5EED
# Offset on the channel stream: without it, round 0 of campaign_seed 0
# would reuse seed 0 — the exact ``sample_network`` draw the Experiment
# constructor made — so the "fresh" round-0 fade would be byte-identical
# to the realisation the allocator was solved on.
CHANNEL_STREAM_TAG = 7919


def round_seed(campaign_seed: int, round_idx: int) -> int:
    """Deterministic per-round seed for channel re-sampling."""
    return campaign_seed * ROUND_SEED_STRIDE + round_idx + CHANNEL_STREAM_TAG


def round_network(fcfg: FedsLLMConfig, campaign_seed: int,
                  round_idx: int, scenario=None) -> dm.Network:
    """The §IV network realisation round ``round_idx`` trains under.

    With a ``scenario`` (see ``repro.sim.scenario``) the draw delegates to
    ``scenario.round_network`` — the scenario decides what persists across
    rounds and what fades.  Without one, this is the legacy ``blockfade``
    semantics: a full fresh draw keyed by round (bit-frozen — the default
    scenario and every pre-scenario campaign depend on it).
    """
    if scenario is not None:
        return scenario.round_network(fcfg, campaign_seed, round_idx)
    return dm.sample_network(fcfg, seed=round_seed(campaign_seed, round_idx))


def localized_round_network(fcfg: FedsLLMConfig, campaign_seed: int,
                            round_idx: int, scenario=None, topology=None):
    """Round draw + topology localization: ``(net, assign)``.

    The scenario draws the round's §IV realisation (vs the BS at the
    origin); the topology then re-anchors each client's wireless hop on its
    attached edge — attachment is recomputed from THIS round's large-scale
    state, so mobility scenarios (``drift``) re-attach clients as they move.
    Without a topology (or under ``star``) this is the plain round draw.
    """
    net = round_network(fcfg, campaign_seed, round_idx, scenario=scenario)
    if topology is None:
        return net, None
    return topology.localize(fcfg, net)


def round_state(exp, campaign_seed: int, round_idx: int, *,
                base_alloc: Optional[Allocation] = None,
                resample: bool = True, reallocate: bool = False,
                realloc_search: str = "warm"):
    """The full per-round pricing of round ``round_idx``, without mutating
    the experiment: ``(net, assign, alloc, eta, timing)``.

    This is the campaign loop's step (a) factored into a *pure* function of
    ``(exp's constructor state, campaign_seed, round_idx)`` — the loop calls
    it to advance the experiment, and the asynchronous execution schedules
    (``repro.des.schedules``) call it to price client run durations at
    arbitrary round indices without disturbing the loop's state.  With
    ``resample=False`` every round prices identically to the constructor
    realisation (the frozen-channel semantics).  ``base_alloc`` is the last
    *solved* allocation the stale-retiming path re-prices (defaults to the
    experiment's current one); under ``reallocate=True`` the allocator
    re-solves jointly and ``eta`` comes back quantized onto the
    ``fcfg.eta_bucket`` grid exactly as ``Experiment.set_eta`` would adopt
    it, so loop and schedule agree bit-for-bit on the round's timing.
    """
    fcfg = exp.fcfg
    if not resample:
        return exp.net, exp.assign, exp.alloc, exp.eta, exp.timing
    # the population model (9th axis) may replace the exact queue pricing
    # with its analytic mean-field model and restrict per-cell re-solves to
    # representative clients; ``exact`` (and any unbound population) leaves
    # every path below bit-identical
    pop = getattr(exp, "population", None)
    net, assign = localized_round_network(fcfg, campaign_seed, round_idx,
                                          scenario=exp.scenario,
                                          topology=exp.topology)
    if reallocate:
        kw = {"eta_search": realloc_search}
        if realloc_search == "warm":
            kw["eta0"] = exp._eta0
        alloc = exp.topology.allocate(fcfg, net, assign, exp._allocate,
                                      strategy=exp.allocator_name,
                                      population=pop, **kw)
        if not alloc.feasible or not np.isfinite(alloc.eta):
            # an infeasible Allocation carries eta=nan on purpose — adopting
            # a fabricated η would silently train on an unsolvable round
            raise ValueError(
                f"round {round_idx}: allocator {exp.allocator_name!r} found "
                f"no feasible allocation on this round's network (scenario "
                f"{exp.scenario.name!r}, topology {exp.topology.name!r}) — "
                f"refusing to adopt η from an infeasible solve")
        eta = quantize_eta(alloc.eta, fcfg.eta_bucket, fcfg.eta_train_max)
    else:
        alloc = retime_allocation(fcfg, net,
                                  exp.alloc if base_alloc is None else base_alloc)
        eta = exp.eta
    timing = exp.topology.round_timing(fcfg, net, alloc, eta, assign,
                                       population=pop)
    return net, assign, alloc, eta, timing


def _transmit_time(bits: float, rate: np.ndarray) -> np.ndarray:
    """bits/rate with rate→0 treated as an outage (+inf, a sure straggler)."""
    rate = np.asarray(rate, float)
    out = np.full_like(rate, np.inf)
    np.divide(bits, rate, out=out, where=rate > 0)
    return out


def retime_allocation(fcfg: FedsLLMConfig, net: dm.Network,
                      alloc: Allocation) -> Allocation:
    """Re-price a *stale* allocation under a fresh channel draw.

    The bandwidth split (b_c, b_s) and model split A stay fixed (the
    allocator is not re-run), but the uplink times are what the new gains
    actually deliver at those bandwidths: t = s / r(b, g_new).  This is the
    source of deadline stragglers when the channel moves against a client
    between allocator solves.
    """
    r_c = dm.rate(alloc.b_c, net.g_c, net.p_c_max, net.N0)
    r_s = dm.rate(alloc.b_s, net.g_s, net.p_s_max, net.N0)
    return dataclasses.replace(
        alloc,
        t_c=_transmit_time(fcfg.s_c_bits, r_c),
        t_s=_transmit_time(fcfg.s_bits, r_s),
    )


def cohort_ids(round_idx: int, num_clients: int, cohort: int,
               seed: int = 0) -> np.ndarray:
    """Elastic cohort: which of the K simulated users train this round.

    ``cohort == num_clients`` degenerates to the identity (every user, every
    round); smaller cohorts are sampled without replacement, keyed by round.
    """
    if cohort >= num_clients:
        return np.arange(num_clients)
    return federated.client_sample(round_idx, num_clients, cohort,
                                   seed=seed + COHORT_STREAM_TAG)


def straggler_mask(round_total: np.ndarray, ids: np.ndarray,
                   deadline: Optional[float]) -> Optional[np.ndarray]:
    """(C,) survivor mask for this round's cohort, or None when no deadline.

    ``round_total`` is the simulated per-user round time (``RoundTiming.total``,
    shape (K,)); survivors are cohort members finishing by the deadline.
    """
    if deadline is None:
        return None
    return federated.deadline_mask(np.asarray(round_total)[ids], deadline)


def round_wall_clock(round_total: np.ndarray, ids: np.ndarray,
                     deadline: Optional[float]) -> float:
    """Simulated seconds the server spends on this round.

    Without a deadline the server waits for the slowest cohort member; with
    one it proceeds at min(slowest finisher, deadline) — stragglers are cut
    off, they don't stretch the round.
    """
    slowest = float(np.max(np.asarray(round_total)[ids]))
    return slowest if deadline is None else min(slowest, float(deadline))
