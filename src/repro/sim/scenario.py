"""First-class, composable channel-dynamics scenarios.

How the §IV wireless network evolves across a campaign used to be an
implicit side effect of ``dm.sample_network(seed)``: every resampled round
teleported users to fresh positions, because the legacy draw conflates
*large-scale* state (geometry → path loss, shadow environment, client
heterogeneity C_k/D_k/f_max — physically fixed for minutes-to-hours) with
*small-scale* fading (coherence ≪ one round of LLM training).  This module
makes that evolution a first-class object: a :class:`Scenario` splits the
two timescales and is pluggable by name through a registry, mirroring the
aggregator/allocator/compressor axes of ``repro.api``:

  ``frozen``         one realisation for the whole campaign (no dynamics)
  ``blockfade``      the legacy semantics, bit-frozen: a full fresh draw —
                     positions included — every round (the default)
  ``geo-blockfade``  fixed geometry + per-round shadow-fading redraws
  ``drift``          random-walk user mobility: positions move a bounded
                     step per round, path loss follows, fading redraws
  ``hetero``         device-class tiers: clients split into CPU/tx-power
                     classes over fixed geometry + per-round fading
  ``outage``         bursty deep fades: per-user extra loss that switches
                     on/off in multi-round bursts over geo-blockfade
  ``shadowing``      Gauss-Markov temporally-correlated shadowing: AR(1)
                     in dB across rounds (lag-1 autocorrelation ρ) with
                     the paper's N(0, σ²) per-round marginal preserved

Every scenario is a *pure function* of ``(fcfg, seed, round)`` — no hidden
state between calls — so campaigns stay bit-reproducible and checkpoint
resume replays exactly the rounds an uninterrupted run would have produced
(``tests/test_scenario.py`` property-tests this for every registered name).

    exp = Experiment.from_config(run_cfg, scenario="geo-blockfade")
    exp.run(num_rounds=20, stream=stream, reallocate=True)

Unknown names raise ``KeyError`` listing the knowns, like every other
registry.  Custom dynamics: subclass :class:`Scenario` and pass the instance
to ``Experiment.from_config(scenario=...)`` (or register it by name).
"""

from __future__ import annotations

import dataclasses
import hashlib
from functools import lru_cache
from typing import Union

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm
from repro.registry import Registry
from repro.sim import events

# Stream tags decorrelating the scenario's auxiliary draws (mobility steps,
# tier assignment, outage bursts, shadowing innovations) from the fading
# stream of the same seed.
DRIFT_STREAM_TAG = 0xD21F7
HETERO_STREAM_TAG = 0x4E7E20
OUTAGE_STREAM_TAG = 0x0074A6E
SHADOW_STREAM_TAG = 0x5AD011

scenarios: Registry = Registry("scenario")


@lru_cache(maxsize=64)
def _base_large_scale(fcfg: FedsLLMConfig, seed: int) -> dm.LargeScaleState:
    """Cached once-per-campaign draw (FedsLLMConfig is frozen ⇒ hashable)."""
    return dm.sample_large_scale(fcfg, seed)


class Scenario:
    """Base class: large-scale state drawn once, fading redrawn per round.

    Subclasses override :meth:`round_large_scale` to evolve the persistent
    state (mobility, tiers) and/or :meth:`round_network` for fully custom
    dynamics.  All methods must be pure in their arguments — determinism in
    ``(seed, round)`` is part of the registry contract.
    """

    name = "scenario"

    # -- large-scale (once per campaign, optionally evolved) ---------------
    def large_scale(self, fcfg: FedsLLMConfig, seed: int) -> dm.LargeScaleState:
        """The campaign's persistent state (round 0 geometry for mobility)."""
        return _base_large_scale(fcfg, seed)

    def round_large_scale(self, fcfg: FedsLLMConfig, campaign_seed: int,
                          round_idx: int) -> dm.LargeScaleState:
        """Large-scale state in effect at ``round_idx`` (default: static)."""
        return self.large_scale(fcfg, campaign_seed)

    # -- realisations ------------------------------------------------------
    def initial_network(self, fcfg: FedsLLMConfig, seed: int) -> dm.Network:
        """The constructor-time realisation the allocator is first solved on."""
        return dm.realize_network(fcfg, self.large_scale(fcfg, seed), seed=seed)

    def round_network(self, fcfg: FedsLLMConfig, campaign_seed: int,
                      round_idx: int) -> dm.Network:
        """The realisation round ``round_idx`` trains under."""
        return dm.realize_network(
            fcfg, self.round_large_scale(fcfg, campaign_seed, round_idx),
            seed=events.round_seed(campaign_seed, round_idx))

    # -- identity ----------------------------------------------------------
    def params(self) -> dict:
        """Constructor parameters that change the dynamics (digest input).

        Subclasses with knobs (mobility step, outage prob, tiers) must
        return them here: two campaigns that share a large-scale draw but
        evolve it differently are different campaigns, and checkpoint
        resume has to be able to tell them apart.
        """
        return {}

    def digest(self, fcfg: FedsLLMConfig, seed: int) -> str:
        """Checkpoint identity: large-scale realisation + dynamics params."""
        h = hashlib.sha1(self.large_scale(fcfg, seed).digest.encode())
        h.update(repr(sorted(self.params().items())).encode())
        return h.hexdigest()[:16]

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"{type(self).__name__}({self.name!r})"


@scenarios.register("frozen")
class FrozenScenario(Scenario):
    """One §IV realisation for the whole campaign — no channel dynamics.

    ``resample_channel=True`` under this scenario degenerates to the
    frozen-channel run bit-exactly (the per-round "redraw" returns the same
    realisation, so retiming re-derives the same uplink times).
    """

    name = "frozen"

    def initial_network(self, fcfg, seed):
        # the legacy draw, for bit-compat with pre-scenario constructors
        return dm.sample_network(fcfg, seed=seed)

    def round_network(self, fcfg, campaign_seed, round_idx):
        return self.initial_network(fcfg, campaign_seed)


@scenarios.register("blockfade")
class BlockFadeScenario(Scenario):
    """The legacy per-round semantics, kept bit-identical (the default).

    Every round is a full fresh ``sample_network`` draw — geometry and
    heterogeneity included — keyed by ``(campaign_seed, round)`` exactly as
    the pre-scenario campaign engine did, so existing campaign goldens and
    determinism tests reproduce bit-for-bit.
    """

    name = "blockfade"

    def initial_network(self, fcfg, seed):
        return dm.sample_network(fcfg, seed=seed)

    def round_network(self, fcfg, campaign_seed, round_idx):
        return events.round_network(fcfg, campaign_seed, round_idx)


@scenarios.register("geo-blockfade")
class GeoBlockFadeScenario(Scenario):
    """Fixed geometry + per-round shadow-fading redraws (ROADMAP item #1).

    User positions, path loss and client heterogeneity are drawn once per
    campaign; only the small-scale fading is redrawn each round.  This is
    the physically-honest block-fading model: fading decorrelates between
    rounds, users do not teleport.
    """

    name = "geo-blockfade"


@scenarios.register("drift")
class DriftScenario(Scenario):
    """Random-walk mobility: users take one bounded step per round.

    Positions at round r are the round-0 geometry plus r i.i.d. Gaussian
    steps of scale ``step_m`` (clipped to the cell), recomputed from scratch
    from the seed each call so round r's network is a pure function of
    ``(seed, r)`` — checkpoint resume replays the walk exactly.
    """

    name = "drift"

    def __init__(self, step_m: float = 20.0):
        self.step_m = float(step_m)

    def params(self):
        return {"step_m": self.step_m}

    def round_large_scale(self, fcfg, campaign_seed, round_idx):
        ls = self.large_scale(fcfg, campaign_seed)
        if round_idx <= 0:
            return ls
        rng = np.random.default_rng([campaign_seed, DRIFT_STREAM_TAG])
        steps = rng.normal(size=(round_idx, ls.K, 2)) * self.step_m
        half = fcfg.area_m / 2.0
        xy = np.clip(ls.xy + steps.sum(axis=0), -half, half)
        return dataclasses.replace(ls, xy=xy, pl_db=dm.path_loss_db(fcfg, xy))


@scenarios.register("hetero")
class HeteroScenario(Scenario):
    """Device/tx-power class tiers over fixed geometry + per-round fading.

    Each client is assigned (deterministically from the seed) to one of
    ``len(f_tiers_hz)`` device classes; its CPU speed and uplink power
    budget come from its class instead of the paper's homogeneous 2 GHz /
    10 dBm.  The delay-minimisation allocator then has real heterogeneity
    to trade bandwidth against.
    """

    name = "hetero"

    def __init__(self, f_tiers_hz=(0.5e9, 1e9, 2e9),
                 p_tiers_dbm=(4.0, 10.0, 16.0)):
        if len(f_tiers_hz) != len(p_tiers_dbm):
            raise ValueError("f_tiers_hz and p_tiers_dbm must align")
        self.f_tiers_hz = tuple(float(f) for f in f_tiers_hz)
        self.p_tiers_dbm = tuple(float(p) for p in p_tiers_dbm)

    def params(self):
        return {"f_tiers_hz": self.f_tiers_hz, "p_tiers_dbm": self.p_tiers_dbm}

    def large_scale(self, fcfg, seed):
        ls = _base_large_scale(fcfg, seed)
        rng = np.random.default_rng([seed, HETERO_STREAM_TAG])
        tier = rng.integers(0, len(self.f_tiers_hz), size=ls.K)
        p_w = np.asarray([dm.dbm_to_watt(p) for p in self.p_tiers_dbm])[tier]
        return dataclasses.replace(
            ls, f_max=np.asarray(self.f_tiers_hz)[tier],
            p_c_max=p_w, p_s_max=p_w)


@scenarios.register("outage")
class OutageScenario(Scenario):
    """Bursty deep fades: per-user extra loss switching in round blocks.

    In each burst window of ``burst_rounds`` consecutive rounds, every user
    is independently in outage with probability ``prob``; an outaged user's
    links lose an extra ``depth_db`` on top of the round's fading draw for
    the whole window (deterministic in ``(seed, round)``: window membership
    is keyed by the window index, not chained round-to-round).
    """

    name = "outage"

    def __init__(self, prob: float = 0.15, depth_db: float = 25.0,
                 burst_rounds: int = 3):
        if not 0.0 <= prob <= 1.0:
            raise ValueError(f"outage prob must be in [0, 1], got {prob}")
        if burst_rounds < 1:
            raise ValueError(f"burst_rounds must be ≥ 1, got {burst_rounds}")
        self.prob = float(prob)
        self.depth_db = float(depth_db)
        self.burst_rounds = int(burst_rounds)

    def params(self):
        return {"prob": self.prob, "depth_db": self.depth_db,
                "burst_rounds": self.burst_rounds}

    def extra_loss_db(self, fcfg, campaign_seed, round_idx) -> np.ndarray:
        window = round_idx // self.burst_rounds
        rng = np.random.default_rng([campaign_seed, OUTAGE_STREAM_TAG, window])
        hit = rng.uniform(size=fcfg.num_clients) < self.prob
        return np.where(hit, self.depth_db, 0.0)

    def round_network(self, fcfg, campaign_seed, round_idx):
        return dm.realize_network(
            fcfg, self.round_large_scale(fcfg, campaign_seed, round_idx),
            seed=events.round_seed(campaign_seed, round_idx),
            extra_loss_db=self.extra_loss_db(fcfg, campaign_seed, round_idx))


@scenarios.register("shadowing")
class ShadowingScenario(Scenario):
    """Gauss-Markov temporally-correlated shadowing (AR(1) in dB).

    The i.i.d. per-round shadow draws of ``geo-blockfade`` ignore that a
    user standing behind the same building fades the same way for many
    rounds.  Here each link's log-normal shadowing follows the classic
    Gudmundson/Gauss-Markov process across rounds r,

        S_0 = σ·ε_0,   S_r = ρ·S_{r-1} + σ·sqrt(1-ρ²)·ε_r,   ε ~ N(0, 1)

    which keeps the stationary per-round marginal N(0, σ²) of the paper's
    §IV model (σ = ``shadow_std_db``) while adding lag-1 autocorrelation ρ.
    The whole innovation stream is keyed by the campaign seed alone and the
    recursion is re-run from round 0 on every call, so round r's field is a
    pure function of ``(seed, r)`` — checkpoint resume replays the process
    exactly (same idiom as the ``drift`` walk).
    """

    name = "shadowing"

    def __init__(self, rho: float = 0.8):
        if not 0.0 <= rho < 1.0:
            raise ValueError(f"shadowing rho must be in [0, 1), got {rho}")
        self.rho = float(rho)

    def params(self):
        return {"rho": self.rho}

    def shadow_db(self, fcfg: FedsLLMConfig, campaign_seed: int,
                  round_idx: int) -> np.ndarray:
        """(2, K) correlated shadow field at ``round_idx`` (fed, main)."""
        rng = np.random.default_rng([campaign_seed, SHADOW_STREAM_TAG])
        eps = rng.normal(size=(round_idx + 1, 2, fcfg.num_clients))
        # closed-form AR(1): S_r = σ(ρ^r ε_0 + sqrt(1-ρ²) Σ_{i≥1} ρ^{r-i} ε_i)
        coef = self.rho ** np.arange(round_idx, -1, -1.0)
        coef[1:] *= np.sqrt(1.0 - self.rho**2)
        return fcfg.shadow_std_db * np.tensordot(coef, eps, axes=(0, 0))

    def round_network(self, fcfg, campaign_seed, round_idx):
        return dm.realize_network(
            fcfg, self.round_large_scale(fcfg, campaign_seed, round_idx),
            seed=events.round_seed(campaign_seed, round_idx),
            shadow_db=self.shadow_db(fcfg, campaign_seed, round_idx))


# the registry stores classes (decorator-friendly); lookups hand out default
# instances, parameterised variants are constructed directly
def get_scenario(spec: Union[str, Scenario]) -> Scenario:
    """Resolve a scenario name or pass an instance through.

    ``get_scenario("geo-blockfade")`` → the registered default instance;
    ``get_scenario(DriftScenario(step_m=50))`` → the object itself.
    Unknown names raise ``KeyError`` listing the registered names.
    """
    if isinstance(spec, Scenario):
        return spec
    if isinstance(spec, type) and issubclass(spec, Scenario):
        return spec()
    cls = scenarios.get(spec)
    return cls()
