"""Scenario × allocator sweep runner.

One call fans a grid of channel-dynamics scenarios × resource-allocation
strategies into identical campaigns over the same ``RunConfig``, collecting
every round of every cell into one tidy long-format records table — the
shape the paper's Fig. 2 comparison wants: the proposed allocator's delay
reduction vs the BA baseline, now reproducible across every scenario family
(mobility, device tiers, outages, …) instead of one frozen draw.

    res = run_sweep(run_cfg, num_rounds=10, stream=stream,
                    scenarios=("blockfade", "geo-blockfade", "drift"),
                    allocators=("proposed", "BA"))
    res.summary()                 # one row per (scenario, allocator) cell
    res.delay_reduction()         # {scenario: % delay saved proposed vs BA}
    res.to_json("results/SWEEP.json")

Also a CLI (the CI sweep smoke):

    PYTHONPATH=src python -m repro.sim.sweep --smoke \
        --scenarios blockfade geo-blockfade --allocators EB BA \
        --rounds 2 --out results/SWEEP_smoke.json
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

DEFAULT_SCENARIOS = ("blockfade", "geo-blockfade")
DEFAULT_ALLOCATORS = ("proposed", "BA")


@dataclass
class SweepResult:
    """A finished sweep: long-format per-round records + grid metadata."""

    records: list[dict]  # one dict per (scenario, allocator, round)
    scenarios: tuple[str, ...]
    allocators: tuple[str, ...]
    num_rounds: int
    meta: dict = field(default_factory=dict)  # cell-level info (traces, η*…)

    def cell(self, scenario: str, allocator: str) -> list[dict]:
        """The per-round records of one grid cell, in round order."""
        return [r for r in self.records
                if r["scenario"] == scenario and r["allocator"] == allocator]

    def summary(self) -> list[dict]:
        """One row per cell: simulated campaign time, final loss, stragglers."""
        out = []
        for s in self.scenarios:
            for a in self.allocators:
                rows = self.cell(s, a)
                if not rows:
                    continue
                slots = sum(r["cohort_size"] for r in rows)
                lost = sum(r["cohort_size"] - r["survivors"] for r in rows)
                out.append({
                    "scenario": s, "allocator": a, "rounds": len(rows),
                    "total_time": rows[-1]["cumulative_time"],
                    "final_loss": rows[-1]["loss_round_start"],
                    "straggler_rate": lost / max(slots, 1),
                    **self.meta.get((s, a), {}),
                })
        return out

    def delay_reduction(self, allocator: str = "proposed",
                        baseline: str = "BA") -> dict[str, float]:
        """Per-scenario % reduction in simulated campaign delay — the
        paper's headline comparison (47.63% on the frozen draw), per
        scenario family."""
        out = {}
        for s in self.scenarios:
            a = self.cell(s, allocator)
            b = self.cell(s, baseline)
            if a and b and b[-1]["cumulative_time"] > 0:
                out[s] = 100.0 * (1.0 - a[-1]["cumulative_time"]
                                  / b[-1]["cumulative_time"])
        return out

    def to_json(self, path: str) -> str:
        """Write the records table (+ summary) as a machine-readable artifact."""
        # label the headline comparison explicitly (and don't fabricate a
        # 0% self-comparison when the grid has a single allocator)
        reduction = None
        if len(self.allocators) >= 2:
            allocator, baseline = self.allocators[0], self.allocators[-1]
            reduction = {"allocator": allocator, "baseline": baseline,
                         "pct_by_scenario": self.delay_reduction(allocator,
                                                                 baseline)}
        payload = {
            "scenarios": list(self.scenarios),
            "allocators": list(self.allocators),
            "num_rounds": self.num_rounds,
            "records": self.records,
            "summary": self.summary(),
            "delay_reduction": reduction,
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        return path


def run_sweep(run_cfg, num_rounds: int, *,
              scenarios: Sequence[str] = DEFAULT_SCENARIOS,
              allocators: Sequence[str] = DEFAULT_ALLOCATORS,
              stream=None, batches=None, batches_fn=None,
              exp_overrides: Optional[dict] = None,
              **campaign_kw) -> SweepResult:
    """Run the same campaign through every (scenario, allocator) cell.

    Each cell builds a fresh ``Experiment`` from ``run_cfg`` (so cells are
    independent and individually deterministic — the whole sweep is a pure
    function of ``(run_cfg, grid)``), then drives ``num_rounds`` rounds with
    identical data/cohort/deadline settings.  ``exp_overrides`` forwards
    extra ``Experiment.from_config`` keywords to every cell (e.g.
    ``{"eta_search": "coarse", "cut": 1}``); ``campaign_kw`` forwards to
    ``Experiment.run`` (e.g. ``cohort=``, ``deadline=``, ``reallocate=``).

    Returns a :class:`SweepResult` whose ``records`` are tidy long-format
    rows — one per round per cell — ready for a dataframe or ``to_json``.
    """
    from repro.api.experiment import Experiment  # deferred: import cycle

    exp_overrides = dict(exp_overrides or {})
    records: list[dict] = []
    meta: dict = {}
    for s in scenarios:
        for a in allocators:
            exp = Experiment.from_config(run_cfg, scenario=s, allocator=a,
                                         **exp_overrides)
            res = exp.run(num_rounds=num_rounds, stream=stream,
                          batches=batches, batches_fn=batches_fn,
                          **campaign_kw)
            for rec in res.records:
                records.append({
                    "scenario": s, "allocator": a, "round": rec.round,
                    "eta": rec.eta, "alloc_T": float(rec.alloc.T),
                    "cohort_size": rec.cohort_size,
                    "survivors": rec.survivors,
                    "round_time": rec.round_time,
                    "cumulative_time": rec.cumulative_time,
                    **rec.metrics,
                })
            meta[(s, a)] = {"trace_count": exp.trace_count,
                            "eta_star": float(exp.alloc.eta),
                            "eta_buckets": len(exp.eta_buckets)}
    return SweepResult(records=records, scenarios=tuple(scenarios),
                       allocators=tuple(allocators), num_rounds=num_rounds,
                       meta=meta)


def main(argv: Optional[list[str]] = None) -> None:
    """CLI sweep (the CI smoke): small grid on the smoke arch, JSON out."""
    import argparse

    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="fedsllm-100m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--scenarios", nargs="+", default=list(DEFAULT_SCENARIOS))
    ap.add_argument("--allocators", nargs="+", default=list(DEFAULT_ALLOCATORS))
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--reallocate", action="store_true",
                    help="re-solve η jointly every round")
    ap.add_argument("--eta", type=float, default=None,
                    help="pin the training η (default: clamped η*)")
    ap.add_argument("--out", default=os.path.join("results", "SWEEP.json"))
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg).replace(lora=LoRAConfig(rank=4))
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=args.clients))
    stream = TokenStream(2, 32 if args.smoke else 64, cfg.vocab_size, seed=0)
    overrides = {} if args.eta is None else {"eta": args.eta}
    res = run_sweep(run_cfg, args.rounds, scenarios=args.scenarios,
                    allocators=args.allocators, stream=stream,
                    cohort=args.cohort, reallocate=args.reallocate,
                    exp_overrides=overrides)
    for row in res.summary():
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    if len(args.allocators) >= 2:
        for s, pct in res.delay_reduction(args.allocators[0],
                                          args.allocators[-1]).items():
            print(f"# {s}: {args.allocators[0]} vs {args.allocators[-1]} "
                  f"delay reduction {pct:.2f}%")
    print(f"# wrote {res.to_json(args.out)} ({len(res.records)} records)")


if __name__ == "__main__":
    main()
