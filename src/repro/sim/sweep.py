"""Topology × scenario × allocator × schedule × local-algo × workload ×
population sweep.

One call fans a grid of network topologies × channel-dynamics scenarios ×
resource-allocation strategies × execution schedules × local-update
algorithms × data workloads × client-population models (``repro.pop``:
``exact`` | ``compact`` | ``meanfield``) into identical campaigns over the same
``RunConfig``, collecting every round of every cell into one tidy
long-format records table — the shape the paper's Fig. 2 comparison wants:
the proposed allocator's delay reduction vs the BA baseline, reproducible
across every scenario family (mobility, device tiers, outages, …), per
network graph (flat star vs hierarchical edge-cloud, …), per execution
discipline (round-synchronous vs pipelined vs asynchronous —
``repro.des.schedules``), and now per client-drift regime: the
``local_algos`` axis (``gd`` | ``fedprox`` | ``scaffold``) crossed with the
``workloads`` axis (``iid`` | the skew families) is where the learning-side
strategies finally separate (``repro.fl``).

    res = run_sweep(run_cfg, num_rounds=10, stream=stream,
                    topologies=("star", "edge-cloud"),
                    scenarios=("geo-blockfade", "drift"),
                    allocators=("proposed", "BA"),
                    schedules=("sync", "pipelined"),
                    local_algos=("gd", "fedprox", "scaffold"),
                    workloads=("iid", "dirichlet"))
    res.summary()           # one row per grid cell
    res.delay_reduction()   # % delay saved vs BA, per remaining grid axes
    res.schedule_speedup()  # % simulated time saved vs the sync schedule
    res.local_algo_gain()   # % final-loss reduction vs gd, per cell
    res.to_json("results/SWEEP.json")

Also a CLI (the CI sweep smokes):

    PYTHONPATH=src python -m repro.sim.sweep --smoke \
        --local-algos gd fedprox --workloads iid dirichlet \
        --allocators EB --rounds 2 --out results/SWEEP_local.json
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from itertools import product
from typing import Optional, Sequence

import numpy as np

DEFAULT_SCENARIOS = ("blockfade", "geo-blockfade")


def _topo_label(spec) -> str:
    """Record/JSON label of a topology grid entry.

    Names pass through; ``Topology`` instances label as ``name`` or
    ``name+<backhaul_model>`` under a queued backhaul, so the queued
    variant of a graph is a distinct grid cell from its serial default
    (the records table is JSON — it carries labels, never objects).
    """
    if isinstance(spec, str):
        return spec
    model = getattr(spec, "backhaul_model", "serial")
    return spec.name if model == "serial" else f"{spec.name}+{model}"
DEFAULT_ALLOCATORS = ("proposed", "BA")
DEFAULT_TOPOLOGIES = ("star",)
DEFAULT_SCHEDULES = ("sync",)
DEFAULT_LOCAL_ALGOS = ("gd",)
DEFAULT_WORKLOADS = ("iid",)
DEFAULT_POPULATIONS = ("exact",)


def _pop_label(spec) -> str:
    """Record/JSON label of a population grid entry (name or instance)."""
    return spec if isinstance(spec, str) else spec.name


@dataclass
class SweepResult:
    """A finished sweep: long-format per-round records + grid metadata."""

    records: list[dict]  # one dict per (topology, scenario, allocator,
    #                      schedule, local_algo, workload, population, round)
    scenarios: tuple[str, ...]
    allocators: tuple[str, ...]
    num_rounds: int
    meta: dict = field(default_factory=dict)  # cell-level info (traces, η*…)
    topologies: tuple[str, ...] = DEFAULT_TOPOLOGIES
    schedules: tuple[str, ...] = DEFAULT_SCHEDULES
    local_algos: tuple[str, ...] = DEFAULT_LOCAL_ALGOS
    workloads: tuple[str, ...] = DEFAULT_WORKLOADS
    populations: tuple[str, ...] = DEFAULT_POPULATIONS

    _AXIS_ARG = {"topologies": "topology", "schedules": "schedule",
                 "local_algos": "local_algo", "workloads": "workload",
                 "populations": "population"}

    def cell(self, scenario: str, allocator: str,
             topology: Optional[str] = None,
             schedule: Optional[str] = None,
             local_algo: Optional[str] = None,
             workload: Optional[str] = None,
             population: Optional[str] = None) -> list[dict]:
        """The per-round records of one grid cell, in round order.

        ``topology``/``schedule``/``local_algo``/``workload``/``population``
        may be omitted only when the grid has a single entry on that axis
        (the pre-axis call signatures); on a multi-entry grid an explicit
        name is required — silently merging graphs, disciplines or drift
        regimes would hand callers interleaved rounds from different
        campaigns."""
        topology = self._only("topologies", topology)
        schedule = self._only("schedules", schedule)
        local_algo = self._only("local_algos", local_algo)
        workload = self._only("workloads", workload)
        population = self._only("populations", population)
        return [r for r in self.records
                if r["scenario"] == scenario and r["allocator"] == allocator
                and r.get("topology", "star") == topology
                and r.get("schedule", "sync") == schedule
                and r.get("local_algo", "gd") == local_algo
                and r.get("workload", "iid") == workload
                and r.get("population", "exact") == population]

    def _only(self, axis: str, value: Optional[str]) -> str:
        entries = getattr(self, axis)
        if value is None:
            if len(entries) > 1:
                arg = self._AXIS_ARG[axis]
                raise ValueError(f"this sweep spans {axis} {entries}; pass "
                                 f"cell(scenario, allocator, {arg}=...)")
            return entries[0]
        return value

    def _grid(self):
        yield from product(self.topologies, self.scenarios, self.allocators,
                           self.schedules, self.local_algos, self.workloads,
                           self.populations)

    def _key(self, topology: str, scenario: str, schedule: str,
             local_algo: str = None, workload: str = None,
             population: str = None) -> str:
        """Reporting key: scenario, prefixed/suffixed by whichever extra
        axes the grid actually spans (single-axis grids keep the short
        pre-axis keys, e.g. ``"blockfade"`` or ``"star/blockfade"``)."""
        key = scenario if len(self.topologies) == 1 else f"{topology}/{scenario}"
        if len(self.schedules) > 1:
            key = f"{key}/{schedule}"
        if local_algo is not None and len(self.local_algos) > 1:
            key = f"{key}/{local_algo}"
        if workload is not None and len(self.workloads) > 1:
            key = f"{key}/{workload}"
        if population is not None and len(self.populations) > 1:
            key = f"{key}/{population}"
        return key

    def summary(self) -> list[dict]:
        """One row per cell: simulated campaign time, final loss, stragglers."""
        out = []
        for t, s, a, d, la, w, p in self._grid():
            rows = self.cell(s, a, t, d, la, w, p)
            if not rows:
                continue
            slots = sum(r["cohort_size"] for r in rows)
            lost = sum(r["cohort_size"] - r["survivors"] for r in rows)
            out.append({
                "topology": t, "scenario": s, "allocator": a, "schedule": d,
                "local_algo": la, "workload": w, "population": p,
                "rounds": len(rows),
                "total_time": rows[-1]["cumulative_time"],
                "final_loss": rows[-1]["loss_round_start"],
                "straggler_rate": lost / max(slots, 1),
                **self.meta.get((t, s, a, d, la, w, p), {}),
            })
        return out

    def delay_reduction(self, allocator: str = "proposed",
                        baseline: str = "BA") -> dict[str, float]:
        """% reduction in simulated campaign delay — the paper's headline
        comparison (47.63% on the frozen draw), per scenario family and,
        when the grid spans several topologies/schedules, per network graph
        and per execution discipline (keys become
        ``"topology/scenario[/schedule]"``)."""
        out = {}
        for t, s, d, la, w, p in product(self.topologies, self.scenarios,
                                         self.schedules, self.local_algos,
                                         self.workloads, self.populations):
            a = self.cell(s, allocator, t, d, la, w, p)
            b = self.cell(s, baseline, t, d, la, w, p)
            if a and b and b[-1]["cumulative_time"] > 0:
                out[self._key(t, s, d, la, w, p)] = 100.0 * (
                    1.0 - a[-1]["cumulative_time"]
                    / b[-1]["cumulative_time"])
        return out

    def schedule_speedup(self, baseline: str = "sync") -> dict[str, float]:
        """% simulated campaign time saved by each non-baseline schedule vs
        ``baseline`` on the same (topology, scenario, allocator) cell —
        the event-driven counterpart of ``delay_reduction`` (keys
        ``"topology/scenario/allocator/schedule"``; requires the baseline
        schedule in the grid)."""
        out = {}
        if baseline not in self.schedules:
            return out
        for t, s, a, la, w, p in product(self.topologies, self.scenarios,
                                         self.allocators, self.local_algos,
                                         self.workloads, self.populations):
            base = self.cell(s, a, t, baseline, la, w, p)
            if not base or base[-1]["cumulative_time"] <= 0:
                continue
            for d in self.schedules:
                if d == baseline:
                    continue
                rows = self.cell(s, a, t, d, la, w, p)
                if rows:
                    key = f"{t}/{s}/{a}/{d}"
                    if len(self.local_algos) > 1:
                        key = f"{key}/{la}"
                    if len(self.workloads) > 1:
                        key = f"{key}/{w}"
                    if len(self.populations) > 1:
                        key = f"{key}/{p}"
                    out[key] = 100.0 * (
                        1.0 - rows[-1]["cumulative_time"]
                        / base[-1]["cumulative_time"])
        return out

    def local_algo_gain(self, baseline: str = "gd") -> dict[str, float]:
        """% final-loss reduction of each non-baseline local algorithm vs
        ``baseline`` on the same (topology, scenario, allocator, schedule,
        workload) cell — positive means the drift-corrected algorithm ended
        the campaign at a lower global loss.  The final loss is the last
        round's ``loss_round_start`` (the global model after every previous
        aggregation), the same convention as ``summary()``.  Keys are
        ``"scenario[/…]/workload/local_algo"``; requires the baseline
        algorithm in the grid."""
        out = {}
        if baseline not in self.local_algos:
            return out
        for t, s, a, d, w, p in product(self.topologies, self.scenarios,
                                        self.allocators, self.schedules,
                                        self.workloads, self.populations):
            base = self.cell(s, a, t, d, baseline, w, p)
            if not base or base[-1]["loss_round_start"] <= 0:
                continue
            for la in self.local_algos:
                if la == baseline:
                    continue
                rows = self.cell(s, a, t, d, la, w, p)
                if rows:
                    key = f"{self._key(t, s, d)}/{w}/{la}"
                    if len(self.allocators) > 1:
                        key = f"{a}:{key}"
                    if len(self.populations) > 1:
                        key = f"{key}/{p}"
                    out[key] = 100.0 * (
                        1.0 - rows[-1]["loss_round_start"]
                        / base[-1]["loss_round_start"])
        return out

    def to_json(self, path: str) -> str:
        """Write the records table (+ summary) as a machine-readable artifact."""
        # label the headline comparison explicitly (and don't fabricate a
        # 0% self-comparison when the grid has a single allocator)
        reduction = None
        if len(self.allocators) >= 2:
            allocator, baseline = self.allocators[0], self.allocators[-1]
            reduction = {"allocator": allocator, "baseline": baseline,
                         "pct_by_scenario": self.delay_reduction(allocator,
                                                                 baseline)}
        payload = {
            "topologies": list(self.topologies),
            "scenarios": list(self.scenarios),
            "allocators": list(self.allocators),
            "schedules": list(self.schedules),
            "local_algos": list(self.local_algos),
            "workloads": list(self.workloads),
            "populations": list(self.populations),
            "num_rounds": self.num_rounds,
            "records": self.records,
            "summary": self.summary(),
            "delay_reduction": reduction,
            "schedule_speedup_pct": (self.schedule_speedup()
                                     if len(self.schedules) >= 2 else None),
            "local_algo_gain_pct": (self.local_algo_gain()
                                    if len(self.local_algos) >= 2 else None),
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        return path


def run_sweep(run_cfg, num_rounds: int, *,
              scenarios: Sequence[str] = DEFAULT_SCENARIOS,
              allocators: Sequence[str] = DEFAULT_ALLOCATORS,
              topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
              schedules: Sequence[str] = DEFAULT_SCHEDULES,
              local_algos: Sequence[str] = DEFAULT_LOCAL_ALGOS,
              workloads: Sequence[str] = DEFAULT_WORKLOADS,
              populations: Sequence[str] = DEFAULT_POPULATIONS,
              stream=None, batches=None, batches_fn=None,
              exp_overrides: Optional[dict] = None,
              **campaign_kw) -> SweepResult:
    """Run the same campaign through every (topology, scenario, allocator,
    schedule, local_algo, workload, population) cell.

    Each cell builds a fresh ``Experiment`` from ``run_cfg`` (so cells are
    independent and individually deterministic — the whole sweep is a pure
    function of ``(run_cfg, grid)``), then drives ``num_rounds`` rounds with
    identical data/cohort/deadline settings.  ``exp_overrides`` forwards
    extra ``Experiment.from_config`` keywords to every cell (e.g.
    ``{"eta_search": "coarse", "cut": 1}``); ``campaign_kw`` forwards to
    ``Experiment.run`` (e.g. ``cohort=``, ``deadline=``, ``reallocate=``).
    Non-star topologies need geometry-carrying scenarios in the grid (e.g.
    ``geo-blockfade``/``drift`` — not the legacy ``blockfade``); async
    schedules run the full population regardless of ``cohort=``; non-``iid``
    workloads shape per-client *stream* reads, so they require ``stream=``.

    Returns a :class:`SweepResult` whose ``records`` are tidy long-format
    rows — one per round per cell — ready for a dataframe or ``to_json``.
    """
    from repro.api.experiment import Experiment  # deferred: import cycle

    if stream is None and any(w != "iid" for w in workloads):
        raise ValueError(f"workloads={tuple(workloads)} include non-iid "
                         f"entries, which require stream= data")
    exp_overrides = dict(exp_overrides or {})
    records: list[dict] = []
    meta: dict = {}
    for t, s, a, d, la, w, p in product(topologies, scenarios, allocators,
                                        schedules, local_algos, workloads,
                                        populations):
        exp = Experiment.from_config(run_cfg, scenario=s,
                                     allocator=a, topology=t,
                                     schedule=d, local_algo=la,
                                     workload=w, population=p,
                                     **exp_overrides)
        t = _topo_label(t)  # instances become labels in records/meta
        p = _pop_label(p)
        res = exp.run(num_rounds=num_rounds, stream=stream,
                      batches=batches, batches_fn=batches_fn,
                      **campaign_kw)
        for rec in res.records:
            records.append({
                "topology": t, "scenario": s, "allocator": a,
                "schedule": d, "local_algo": la, "workload": w,
                "population": p,
                "round": rec.round,
                "eta": rec.eta, "alloc_T": float(rec.alloc.T),
                "cohort_size": rec.cohort_size,
                "survivors": rec.survivors,
                "round_time": rec.round_time,
                "cumulative_time": rec.cumulative_time,
                **rec.metrics,
            })
        meta[(t, s, a, d, la, w, p)] = {"trace_count": exp.trace_count,
                                        "eta_star": float(exp.alloc.eta),
                                        "eta_buckets": len(exp.eta_buckets)}
    return SweepResult(records=records, scenarios=tuple(scenarios),
                       allocators=tuple(allocators), num_rounds=num_rounds,
                       meta=meta,
                       topologies=tuple(_topo_label(t) for t in topologies),
                       schedules=tuple(schedules),
                       local_algos=tuple(local_algos),
                       workloads=tuple(workloads),
                       populations=tuple(_pop_label(p) for p in populations))


def main(argv: Optional[list[str]] = None) -> None:
    """CLI sweep (the CI smoke): small grid on the smoke arch, JSON out."""
    import argparse

    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="fedsllm-100m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--scenarios", nargs="+", default=list(DEFAULT_SCENARIOS))
    ap.add_argument("--allocators", nargs="+", default=list(DEFAULT_ALLOCATORS))
    ap.add_argument("--topologies", nargs="+",
                    default=list(DEFAULT_TOPOLOGIES),
                    help="network graphs (repro.net.topology); non-star "
                         "need geometry scenarios like geo-blockfade")
    ap.add_argument("--schedules", nargs="+", default=list(DEFAULT_SCHEDULES),
                    help="execution disciplines (repro.des.schedules): "
                         "sync | pipelined | async | semi-async")
    ap.add_argument("--local-algos", nargs="+",
                    default=list(DEFAULT_LOCAL_ALGOS),
                    help="client local-update rules (repro.fl.local_algos): "
                         "gd | fedprox | scaffold")
    ap.add_argument("--workloads", nargs="+", default=list(DEFAULT_WORKLOADS),
                    help="per-client data distributions "
                         "(repro.fl.workloads): iid | quantity-skew | "
                         "length-skew | dirichlet")
    ap.add_argument("--populations", nargs="+",
                    default=list(DEFAULT_POPULATIONS),
                    help="client-population models (repro.pop): exact | "
                         "compact | meanfield — 'compact'/'meanfield' make "
                         "large --clients campaigns O(cohort) per round")
    ap.add_argument("--backhaul-model", default="serial",
                    choices=("serial", "fifo", "ps"),
                    help="edge→cloud backhaul discipline for every "
                         "hierarchical topology on the grid: 'serial' is "
                         "the legacy per-cell pipe; 'fifo'/'ps' share one "
                         "queued metro link and turn on the wait-aware "
                         "allocator loop (cells label as e.g. "
                         "'edge-cloud+fifo')")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--reallocate", action="store_true",
                    help="re-solve η jointly every round")
    ap.add_argument("--eta", type=float, default=None,
                    help="pin the training η (default: clamped η*)")
    ap.add_argument("--out", default=os.path.join("results", "SWEEP.json"))
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg).replace(lora=LoRAConfig(rank=4))
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=args.clients))
    stream = TokenStream(2, 32 if args.smoke else 64, cfg.vocab_size, seed=0)
    overrides = {} if args.eta is None else {"eta": args.eta}
    topo_grid = list(args.topologies)
    if args.backhaul_model != "serial":
        from repro.net.topology import get_topology

        # star has no backhaul leg — only hierarchical graphs re-instantiate
        topo_grid = [t if t == "star" else
                     type(get_topology(t))(backhaul_model=args.backhaul_model)
                     for t in topo_grid]
    res = run_sweep(run_cfg, args.rounds, scenarios=args.scenarios,
                    allocators=args.allocators, topologies=topo_grid,
                    schedules=args.schedules, local_algos=args.local_algos,
                    workloads=args.workloads, populations=args.populations,
                    stream=stream,
                    cohort=args.cohort, reallocate=args.reallocate,
                    exp_overrides=overrides)
    for row in res.summary():
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    if len(args.allocators) >= 2:
        for s, pct in res.delay_reduction(args.allocators[0],
                                          args.allocators[-1]).items():
            print(f"# {s}: {args.allocators[0]} vs {args.allocators[-1]} "
                  f"delay reduction {pct:.2f}%")
    for key, pct in res.schedule_speedup().items():
        print(f"# {key}: simulated time saved vs sync {pct:.2f}%")
    for key, pct in res.local_algo_gain().items():
        print(f"# {key}: final-loss reduction vs gd {pct:.2f}%")
    print(f"# wrote {res.to_json(args.out)} ({len(res.records)} records)")


if __name__ == "__main__":
    main()
