"""Topology × scenario × allocator sweep runner.

One call fans a grid of network topologies × channel-dynamics scenarios ×
resource-allocation strategies into identical campaigns over the same
``RunConfig``, collecting every round of every cell into one tidy
long-format records table — the shape the paper's Fig. 2 comparison wants:
the proposed allocator's delay reduction vs the BA baseline, reproducible
across every scenario family (mobility, device tiers, outages, …) and now
per network graph (flat star vs hierarchical edge-cloud, …).

    res = run_sweep(run_cfg, num_rounds=10, stream=stream,
                    topologies=("star", "edge-cloud"),
                    scenarios=("geo-blockfade", "drift"),
                    allocators=("proposed", "BA"))
    res.summary()          # one row per (topology, scenario, allocator) cell
    res.delay_reduction()  # % delay saved vs BA, per topology × scenario
    res.to_json("results/SWEEP.json")

Also a CLI (the CI sweep smokes):

    PYTHONPATH=src python -m repro.sim.sweep --smoke \
        --topologies star edge-cloud --scenarios geo-blockfade drift \
        --allocators proposed BA --rounds 2 --out results/SWEEP_hier.json
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

DEFAULT_SCENARIOS = ("blockfade", "geo-blockfade")
DEFAULT_ALLOCATORS = ("proposed", "BA")
DEFAULT_TOPOLOGIES = ("star",)


@dataclass
class SweepResult:
    """A finished sweep: long-format per-round records + grid metadata."""

    records: list[dict]  # one dict per (topology, scenario, allocator, round)
    scenarios: tuple[str, ...]
    allocators: tuple[str, ...]
    num_rounds: int
    meta: dict = field(default_factory=dict)  # cell-level info (traces, η*…)
    topologies: tuple[str, ...] = DEFAULT_TOPOLOGIES

    def cell(self, scenario: str, allocator: str,
             topology: Optional[str] = None) -> list[dict]:
        """The per-round records of one grid cell, in round order.

        ``topology`` may be omitted only on a single-topology grid (the
        pre-topology call signature); on a multi-topology grid an explicit
        name is required — silently merging graphs would hand callers
        interleaved rounds from different campaigns."""
        if topology is None:
            if len(self.topologies) > 1:
                raise ValueError(
                    f"this sweep spans topologies {self.topologies}; "
                    f"pass cell(scenario, allocator, topology=...)")
            topology = self.topologies[0]
        return [r for r in self.records
                if r["scenario"] == scenario and r["allocator"] == allocator
                and r.get("topology", "star") == topology]

    def summary(self) -> list[dict]:
        """One row per cell: simulated campaign time, final loss, stragglers."""
        out = []
        for t in self.topologies:
            for s in self.scenarios:
                for a in self.allocators:
                    rows = self.cell(s, a, t)
                    if not rows:
                        continue
                    slots = sum(r["cohort_size"] for r in rows)
                    lost = sum(r["cohort_size"] - r["survivors"] for r in rows)
                    out.append({
                        "topology": t, "scenario": s, "allocator": a,
                        "rounds": len(rows),
                        "total_time": rows[-1]["cumulative_time"],
                        "final_loss": rows[-1]["loss_round_start"],
                        "straggler_rate": lost / max(slots, 1),
                        **self.meta.get((t, s, a), {}),
                    })
        return out

    def delay_reduction(self, allocator: str = "proposed",
                        baseline: str = "BA") -> dict[str, float]:
        """% reduction in simulated campaign delay — the paper's headline
        comparison (47.63% on the frozen draw), per scenario family and,
        when the grid spans several topologies, per network graph (keys
        become ``"topology/scenario"``)."""
        out = {}
        for t in self.topologies:
            for s in self.scenarios:
                a = self.cell(s, allocator, t)
                b = self.cell(s, baseline, t)
                if a and b and b[-1]["cumulative_time"] > 0:
                    key = s if len(self.topologies) == 1 else f"{t}/{s}"
                    out[key] = 100.0 * (1.0 - a[-1]["cumulative_time"]
                                        / b[-1]["cumulative_time"])
        return out

    def to_json(self, path: str) -> str:
        """Write the records table (+ summary) as a machine-readable artifact."""
        # label the headline comparison explicitly (and don't fabricate a
        # 0% self-comparison when the grid has a single allocator)
        reduction = None
        if len(self.allocators) >= 2:
            allocator, baseline = self.allocators[0], self.allocators[-1]
            reduction = {"allocator": allocator, "baseline": baseline,
                         "pct_by_scenario": self.delay_reduction(allocator,
                                                                 baseline)}
        payload = {
            "topologies": list(self.topologies),
            "scenarios": list(self.scenarios),
            "allocators": list(self.allocators),
            "num_rounds": self.num_rounds,
            "records": self.records,
            "summary": self.summary(),
            "delay_reduction": reduction,
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        return path


def run_sweep(run_cfg, num_rounds: int, *,
              scenarios: Sequence[str] = DEFAULT_SCENARIOS,
              allocators: Sequence[str] = DEFAULT_ALLOCATORS,
              topologies: Sequence[str] = DEFAULT_TOPOLOGIES,
              stream=None, batches=None, batches_fn=None,
              exp_overrides: Optional[dict] = None,
              **campaign_kw) -> SweepResult:
    """Run the same campaign through every (topology, scenario, allocator)
    cell.

    Each cell builds a fresh ``Experiment`` from ``run_cfg`` (so cells are
    independent and individually deterministic — the whole sweep is a pure
    function of ``(run_cfg, grid)``), then drives ``num_rounds`` rounds with
    identical data/cohort/deadline settings.  ``exp_overrides`` forwards
    extra ``Experiment.from_config`` keywords to every cell (e.g.
    ``{"eta_search": "coarse", "cut": 1}``); ``campaign_kw`` forwards to
    ``Experiment.run`` (e.g. ``cohort=``, ``deadline=``, ``reallocate=``).
    Non-star topologies need geometry-carrying scenarios in the grid (e.g.
    ``geo-blockfade``/``drift`` — not the legacy ``blockfade``).

    Returns a :class:`SweepResult` whose ``records`` are tidy long-format
    rows — one per round per cell — ready for a dataframe or ``to_json``.
    """
    from repro.api.experiment import Experiment  # deferred: import cycle

    exp_overrides = dict(exp_overrides or {})
    records: list[dict] = []
    meta: dict = {}
    for t in topologies:
        for s in scenarios:
            for a in allocators:
                exp = Experiment.from_config(run_cfg, scenario=s, allocator=a,
                                             topology=t, **exp_overrides)
                res = exp.run(num_rounds=num_rounds, stream=stream,
                              batches=batches, batches_fn=batches_fn,
                              **campaign_kw)
                for rec in res.records:
                    records.append({
                        "topology": t, "scenario": s, "allocator": a,
                        "round": rec.round,
                        "eta": rec.eta, "alloc_T": float(rec.alloc.T),
                        "cohort_size": rec.cohort_size,
                        "survivors": rec.survivors,
                        "round_time": rec.round_time,
                        "cumulative_time": rec.cumulative_time,
                        **rec.metrics,
                    })
                meta[(t, s, a)] = {"trace_count": exp.trace_count,
                                   "eta_star": float(exp.alloc.eta),
                                   "eta_buckets": len(exp.eta_buckets)}
    return SweepResult(records=records, scenarios=tuple(scenarios),
                       allocators=tuple(allocators), num_rounds=num_rounds,
                       meta=meta, topologies=tuple(topologies))


def main(argv: Optional[list[str]] = None) -> None:
    """CLI sweep (the CI smoke): small grid on the smoke arch, JSON out."""
    import argparse

    from repro.config import (FedsLLMConfig, LoRAConfig, RunConfig, SHAPES,
                              get_arch, smoke_variant)
    from repro.data.tokens import TokenStream

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="fedsllm-100m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--scenarios", nargs="+", default=list(DEFAULT_SCENARIOS))
    ap.add_argument("--allocators", nargs="+", default=list(DEFAULT_ALLOCATORS))
    ap.add_argument("--topologies", nargs="+",
                    default=list(DEFAULT_TOPOLOGIES),
                    help="network graphs (repro.net.topology); non-star "
                         "need geometry scenarios like geo-blockfade")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--reallocate", action="store_true",
                    help="re-solve η jointly every round")
    ap.add_argument("--eta", type=float, default=None,
                    help="pin the training η (default: clamped η*)")
    ap.add_argument("--out", default=os.path.join("results", "SWEEP.json"))
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg).replace(lora=LoRAConfig(rank=4))
    run_cfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        fedsllm=FedsLLMConfig(num_clients=args.clients))
    stream = TokenStream(2, 32 if args.smoke else 64, cfg.vocab_size, seed=0)
    overrides = {} if args.eta is None else {"eta": args.eta}
    res = run_sweep(run_cfg, args.rounds, scenarios=args.scenarios,
                    allocators=args.allocators, topologies=args.topologies,
                    stream=stream,
                    cohort=args.cohort, reallocate=args.reallocate,
                    exp_overrides=overrides)
    for row in res.summary():
        print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
    if len(args.allocators) >= 2:
        for s, pct in res.delay_reduction(args.allocators[0],
                                          args.allocators[-1]).items():
            print(f"# {s}: {args.allocators[0]} vs {args.allocators[-1]} "
                  f"delay reduction {pct:.2f}%")
    print(f"# wrote {res.to_json(args.out)} ({len(res.records)} records)")


if __name__ == "__main__":
    main()
