"""Campaign simulation: multi-round scenarios over time-varying channels.

``campaign`` drives an ``Experiment`` through many global rounds (the engine
behind ``Experiment.run``); ``events`` generates the per-round scenario —
block-fading channel draws, elastic cohorts, deadline straggler masks — all
deterministically keyed by ``(campaign_seed, round)``.
"""

from repro.sim import events
from repro.sim.campaign import (CampaignResult, RoundRecord, run_campaign,
                                stream_batcher)

__all__ = ["CampaignResult", "RoundRecord", "run_campaign", "stream_batcher",
           "events"]
