"""Campaign simulation: multi-round scenarios over time-varying channels.

``campaign`` drives an ``Experiment`` through many global rounds (the engine
behind ``Experiment.run``); ``scenario`` defines the channel dynamics as
first-class, name-registered objects — ``frozen`` | ``blockfade`` |
``geo-blockfade`` | ``drift`` | ``hetero`` | ``outage`` | ``shadowing`` —
splitting the once-per-campaign large-scale state from per-round fading;
``events`` generates the remaining per-round events (elastic cohorts,
deadline straggler masks, stale-allocation retiming, topology-localized
round draws) deterministically keyed by ``(campaign_seed, round)``;
``sweep`` fans a grid of topologies × scenarios × allocators into one tidy
records table (``Experiment.sweep``).
"""

from repro.sim import events
from repro.sim.campaign import (CampaignResult, RoundRecord, run_campaign,
                                stream_batcher)
from repro.sim.scenario import Scenario, get_scenario, scenarios
from repro.sim.sweep import SweepResult, run_sweep

__all__ = ["CampaignResult", "RoundRecord", "run_campaign", "stream_batcher",
           "Scenario", "get_scenario", "scenarios",
           "SweepResult", "run_sweep",
           "events"]
