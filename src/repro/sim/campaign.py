"""Multi-round campaign engine.

Drives an :class:`repro.api.Experiment` through repeated global rounds under
*time-varying* wireless scenarios: per-round channel evolution delegated to
the experiment's :class:`repro.sim.scenario.Scenario` (block fading, fixed
geometry, mobility, device tiers, outage bursts), optional per-round joint
allocator re-solves, elastic cohorts via ``federated.client_sample`` and
deadline-based straggler masks derived from each round's simulated
:class:`~repro.core.fedsllm.RoundTiming`.  The mask is threaded into the
round function's existing ``mask`` argument, so a fixed-η campaign reuses
ONE jit trace — shapes, dtypes and argument structure are identical every
round — and a joint-η campaign (``reallocate=True``) is bounded by the η
bucket count (asserted by ``tests/test_campaign.py``/``test_scenario.py``).

A campaign is a pure function of ``(RunConfig, seed)``: channel draws,
cohorts and data are all keyed by the absolute round index, so two runs of
the same config are bit-identical and a checkpoint-resumed campaign replays
exactly the rounds an uninterrupted one would have run.

    res = exp.run(num_rounds=20, stream=stream, cohort=8,
                  deadline=5.0, resample_channel=True)
    res.history("loss_round_start"), res.total_time, res.records[3].mask
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import fedsllm
from repro.core.fedsllm import FedsLLMState, RoundTiming
from repro.core.resource_alloc import Allocation
from repro.sim import events

if TYPE_CHECKING:  # pragma: no cover — avoid a repro.api import cycle
    from repro.api.experiment import Experiment


@dataclass
class RoundRecord:
    """Everything one campaign round produced (host-side, reporting-ready)."""

    round: int  # absolute global-round index n
    client_ids: np.ndarray  # (C,) simulated users trained this round
    mask: Optional[np.ndarray]  # (C,) deadline survivors; None = no deadline
    metrics: dict[str, float]  # round metrics, device-synced to floats
    alloc: Allocation  # the allocation this round was priced under
    timing: RoundTiming  # (K,) per-user simulated delays this round
    round_time: float  # simulated seconds this round cost the server
    cumulative_time: float  # simulated campaign wall-clock through this round
    eta: float = 0.0  # training η this round ran at (varies under reallocate)
    # per-event timing records from the execution schedule (dicts in
    # (time, seq) order: complete / timeout / aggregate), and the staleness
    # each surviving update carried (async schedules; None under sync)
    events: Optional[list] = None
    staleness: Optional[np.ndarray] = None
    # (C,) per-client completion times AS THE SCHEDULE PRICED THEM — under
    # ``pipelined`` these differ from ``timing`` (which keeps the §III
    # sequential pricing); the recorded mask/round_time derive from these
    completion: Optional[np.ndarray] = None

    @property
    def cohort_size(self) -> int:
        return len(self.client_ids)

    @property
    def survivors(self) -> int:
        return self.cohort_size if self.mask is None else int(np.sum(self.mask > 0))

    @property
    def stragglers(self) -> int:
        return self.cohort_size - self.survivors


@dataclass
class CampaignResult:
    """A finished campaign: per-round history + final state + why it stopped."""

    records: list[RoundRecord]
    state: FedsLLMState
    total_time: float  # simulated wireless seconds, whole campaign
    rounds_lemma1: int  # Lemma 1 budget a/(1-η) at the training η
    # "num_rounds" | "lemma1" | "checkpoint" (restore already covered the
    # requested rounds — records is then empty)
    stopped_by: str
    scenario: str = "blockfade"  # channel-dynamics family the rounds ran under
    topology: str = "star"  # network graph the rounds ran over
    schedule: str = "sync"  # execution discipline the rounds ran with
    population: str = "exact"  # client-population model the rounds ran with

    @property
    def num_rounds(self) -> int:
        return len(self.records)

    def history(self, metric: str) -> np.ndarray:
        """One metric across rounds, e.g. ``history("loss_round_start")``."""
        return np.asarray([r.metrics[metric] for r in self.records])

    @property
    def straggler_rate(self) -> float:
        """Fraction of cohort slots lost to the deadline over the campaign."""
        slots = sum(r.cohort_size for r in self.records)
        return sum(r.stragglers for r in self.records) / max(slots, 1)


def stream_batcher(stream, num_clients: int) -> Callable[[int, np.ndarray], Any]:
    """Per-round batches for a cohort drawn from ``num_clients`` users.

    Client ``k`` reads its own deterministic position ``r·K + k`` of the
    stream — identical to ``data.tokens.client_batches`` when the cohort is
    the full population, and stable under elastic sampling (a client's data
    does not depend on who else was sampled).
    """

    def fn(round_idx: int, client_ids: np.ndarray):
        per_client = [stream.batch_at(round_idx * num_clients + int(k))
                      for k in client_ids]
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_client)

    return fn


def run_campaign(exp: "Experiment", num_rounds: Optional[int] = None, *,
                 stream=None, batches=None,
                 batches_fn: Optional[Callable[[int, np.ndarray], Any]] = None,
                 cohort: Optional[int] = None,
                 resample_channel: bool = True, reallocate: bool = False,
                 realloc_search: Optional[str] = "warm",
                 deadline: Optional[float] = None,
                 stop_at_lemma1: bool = False,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, resume: bool = False,
                 campaign_seed: Optional[int] = None,
                 on_round: Optional[Callable[[RoundRecord], None]] = None,
                 ) -> CampaignResult:
    """Run a multi-round campaign on ``exp`` (see ``Experiment.run``).

    Data source — exactly one of:
      ``batches_fn(round_idx, client_ids) -> stacked pytree``  (full control)
      ``stream``   a ``TokenStream``; each client reads its own positions
      ``batches``  one fixed stacked pytree reused every round (cohort is
                   then pinned to its leading axis — no elastic sampling)

    Scenario axes:
      ``resample_channel``  fresh §IV network realisation per round, drawn by
          the experiment's *scenario* (``exp.scenario``, see
          ``repro.sim.scenario``) keyed by ``(campaign_seed, round)`` — what
          persists between rounds (geometry, device classes, mobility) is the
          scenario's call.  With ``reallocate=False`` the stale allocation is
          re-priced under the new gains (:func:`events.retime_allocation`);
          with ``reallocate=True`` the experiment's allocator strategy
          re-solves problems (16)/(17) *jointly* every round — per edge cell
          under a hierarchical topology: the solved η* is adopted (quantized
          to the ``fcfg.eta_bucket`` grid via ``Experiment.set_eta``), so
          bandwidth, split AND the Lemma 1/2 schedule all track the channel.
          ``realloc_search`` sets the per-round η-sweep mode; the default
          ``"warm"`` sweeps a ±5-step window around the constructor's solved
          η* — ~10× cheaper and, per the cross-scenario audit in
          ``tests/test_scenario.py``, optimal to <1e-6 of the full sweep
          (pass ``None`` to fall back to the experiment's ``eta_search``).
      ``cohort``    clients trained per round (< K ⇒ elastic subsampling via
          ``federated.client_sample``); default: the full population.
      ``deadline``  simulated seconds; cohort members whose round delay
          exceeds it are masked out of aggregation (``deadline_mask``).

    Stopping & durability:
      ``num_rounds`` is the campaign's ABSOLUTE length: rounds run from the
          state's current global round counter up to ``num_rounds``, so
          ``run(5)`` then ``run(10)`` trains rounds 0–4 then 5–9 (a second
          ``run(5)`` is a no-op, not a replay of the same scenario).
      ``stop_at_lemma1``  cap rounds at Lemma 1's ⌈a/(1−η)⌉ budget (priced
          at the campaign's starting η).
      ``checkpoint_dir``/``checkpoint_every``  periodic + final state saves;
          ``resume=True`` restores the newest checkpoint and replays the
          remaining rounds bit-identically (everything is round-indexed).
          Non-campaign checkpoints, and checkpoints from a different
          campaign — seed, η, allocator, scenario name, large-scale-state
          digest, topology name, attachment digest, execution-schedule,
          local-algorithm, workload or population mismatch — are refused.  Stateful
          local algorithms (scaffold) checkpoint their control variates
          with the model, so resume is bit-identical there too.

    Execution schedule (``exp.schedule``, the 6th axis): ``sync`` (default)
    keeps every semantics above bit-identical; ``pipelined`` re-times
    completions with microbatch overlap (masks/clock follow); ``async`` /
    ``semi-async`` replace the round barrier with a deterministic event
    timeline — round r is the r-th server aggregation, the full population
    rides through the round function and the mask/staleness weights select
    the arrivals (``repro.des.schedules``).  Per-event timing records land
    on ``RoundRecord.events``.
    """
    fcfg = exp.fcfg
    K = fcfg.num_clients
    campaign_seed = exp.seed if campaign_seed is None else campaign_seed
    scenario = exp.scenario

    # --- data source ------------------------------------------------------
    provided = [x is not None for x in (batches_fn, stream, batches)]
    if sum(provided) != 1:
        raise ValueError("provide exactly one of batches_fn= / stream= / batches=")
    fixed_cohort = None
    if batches is not None:
        fixed_cohort = jax.tree.leaves(batches)[0].shape[0]
        batches_fn = lambda r, ids: batches  # noqa: E731
    elif stream is not None:
        # the experiment's workload (7th-axis data heterogeneity) decides
        # what each client reads from the stream; ``iid`` is bit-identical
        # to the legacy stream_batcher
        batches_fn = exp.workload.batcher(stream, K)
    if stream is None and exp.workload.name != "iid":
        raise ValueError(
            f"workload {exp.workload.name!r} shapes per-client stream reads: "
            f"pass stream= (batches=/batches_fn= bypass the workload)")

    if cohort is None:
        cohort = K if fixed_cohort is None else fixed_cohort
    if fixed_cohort is not None and cohort != fixed_cohort:
        raise ValueError(f"cohort={cohort} != leading axis {fixed_cohort} of batches=")
    if not 1 <= cohort <= K:
        raise ValueError(f"cohort={cohort} must be in [1, num_clients={K}]")
    if reallocate and not resample_channel:
        raise ValueError("reallocate=True requires resample_channel=True "
                         "(re-solving the frozen channel draw is a no-op)")

    # --- stopping rule ----------------------------------------------------
    rounds_lemma1 = fedsllm.global_round_count(fcfg, exp.eta)
    if num_rounds is None and not stop_at_lemma1:
        raise ValueError("give num_rounds= and/or stop_at_lemma1=True")
    if stop_at_lemma1 and (num_rounds is None or rounds_lemma1 <= num_rounds):
        target, stopped_by = rounds_lemma1, "lemma1"
    else:
        target, stopped_by = num_rounds, "num_rounds"

    # --- checkpoint / resume ---------------------------------------------
    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    # continue the simulated wall-clock across consecutive run() calls on
    # the same Experiment (a checkpoint restore overrides it below)
    cumulative = float(getattr(exp, "campaign_time", 0.0))
    if resume and ckpt is not None:
        got = ckpt.restore_or_none()
        if got is not None:
            state, meta = got
            # a checkpoint from a different campaign (or not from a campaign
            # at all) would silently splice incompatible runs — refuse
            if "round" not in meta:
                raise ValueError(
                    f"checkpoint in {checkpoint_dir!r} is not a campaign "
                    f"checkpoint (no 'round' metadata — e.g. a standard-"
                    f"training save); refusing to resume from it")
            identity = [("campaign_seed", campaign_seed),
                        ("allocator", exp.allocator_name),
                        ("scenario", scenario.name),
                        ("ls_digest", scenario.digest(fcfg, campaign_seed)),
                        ("topology", exp.topology.name),
                        ("topo_digest", exp.topology.digest(fcfg, scenario,
                                                            campaign_seed)),
                        ("schedule", exp.schedule.name),
                        # params change the timeline (β, buffer_k, M) the
                        # same way scenario/topology params change theirs
                        ("schedule_params",
                         repr(sorted(exp.schedule.params().items()))),
                        # the local algorithm + workload change the
                        # trajectory (and scaffold's checkpointed variates)
                        # the same way schedule params change the timeline
                        ("local_algo", exp.local_algo.name),
                        ("local_algo_params",
                         repr(sorted(exp.local_algo.params().items()))),
                        ("workload", exp.workload.name),
                        ("workload_params",
                         repr(sorted(exp.workload.params().items()))),
                        # the population model changes which clients ride
                        # each round's window (compact/meanfield) — a name
                        # or window/reps mismatch is a different campaign
                        ("population", exp.population.name),
                        ("population_params",
                         repr(sorted(exp.population.params().items()))),
                        ("reallocate", reallocate)]
            if not (reallocate and meta.get("reallocate")):
                # under joint reallocation η is derived per-round state, not
                # campaign identity — every resumed round re-solves it
                identity.append(("eta", exp.eta))
            for field, current in identity:
                if field in meta and meta[field] != current:
                    raise ValueError(
                        f"checkpoint in {checkpoint_dir!r} is from a "
                        f"different campaign: {field}={meta[field]!r} vs "
                        f"this run's {current!r}")
            # stateful local algorithms checkpoint their variates alongside
            # the model ({"model": ..., "algo_state": ...}); legacy saves
            # are the bare model pytree
            if isinstance(state, dict) and "model" in state:
                exp.state = state["model"]
                if exp.local_algo.stateful:
                    exp.algo_state = state["algo_state"]
            else:
                exp.state = state
            cumulative = float(meta.get("cumulative_time", 0.0))
            if int(meta["round"]) >= target:
                stopped_by = "checkpoint"  # restore already covers the ask

    # rounds are ABSOLUTE indices: the campaign picks up at the state's
    # global round counter, so a second run() (or a run() after manual
    # run_round calls) continues the scenario instead of silently replaying
    # round 0's channel draws, cohorts and batches against advanced state
    start = min(int(np.asarray(jax.device_get(exp.state.round))), target)

    base_alloc = exp.alloc  # the last *solved* allocation (retiming input)
    # the population model (9th axis) binds its per-campaign state BEFORE
    # the planner runs: the async timeline asks it which clients to launch
    # (meanfield representatives) and the loop below asks it to compact
    # each round's plan onto the fixed window; re-binding on every run()
    # keeps campaigns pure in (RunConfig, seed) and resume-replayable.
    # ``exact`` binds nothing and every hook is the identity.
    pop = exp.population
    pop.begin_campaign(K, cohort, campaign_seed)
    # the execution schedule (6th axis) decides which client states feed
    # each aggregation, at what staleness weight, and what the round costs
    # on the simulated clock; ``sync`` replays the legacy event order
    # bit-identically, the async family pre-simulates the whole timeline
    search = exp._eta_search if realloc_search is None else realloc_search
    planner = exp.schedule.planner(
        exp, campaign_seed=campaign_seed, start=start, target=target,
        cohort=cohort, fixed_cohort=fixed_cohort, deadline=deadline,
        resample_channel=resample_channel, reallocate=reallocate,
        realloc_search=search)
    records: list[RoundRecord] = []
    for r in range(start, target):
        # (a) per-round scenario: channel evolution + re-attachment +
        # allocation + timing (``events.round_state`` — under
        # reallocate=True problems (16)/(17) re-solve jointly on this
        # round's realisation, per edge cell under a hierarchical topology,
        # and the solved η* is adopted quantized onto the η-bucket grid so
        # the Lemma 1/2 schedule tracks the channel without recompiling)
        if resample_channel:
            # timeline planners (async) already priced every round while
            # simulating run durations — reuse instead of re-solving
            priced = getattr(planner, "pricing", {}).get(r)
            net, assign, alloc, _, timing = (
                priced if priced is not None else events.round_state(
                    exp, campaign_seed, r, base_alloc=base_alloc,
                    reallocate=reallocate, realloc_search=search))
            exp.net, exp.assign, exp.alloc = net, assign, alloc
            if reallocate:
                base_alloc = alloc
                exp.set_eta(alloc.eta)
            exp.timing = timing

        # (b) elastic cohort + (c) schedule: completion events → straggler
        # mask, staleness weights and the round's simulated wall-clock
        ids = (np.arange(cohort) if fixed_cohort is not None
               else events.cohort_ids(r, K, cohort, seed=campaign_seed))
        plan = planner.round_plan(r, ids)
        if plan.client_ids is not None:  # async family: full population
            ids = plan.client_ids
        # population compaction: gather the arrivals + in-flight window of
        # a K-sized async plan onto the fixed (C,) window (identity under
        # ``exact`` and for sync-family plans)
        plan, ids = pop.compact_plan(plan, ids, r)
        mask_np = plan.mask
        mask = None if mask_np is None else jnp.asarray(mask_np)
        round_time = plan.round_time

        # (d) train the round through the ONE jitted round function
        res = exp.run_round(pop.device_batch(batches_fn(r, ids)),
                            mask=mask, client_ids=ids,
                            weight_scale=plan.weight_scale,
                            update_scale=plan.update_scale)

        cumulative += round_time
        rec = RoundRecord(
            round=r, client_ids=np.asarray(ids), mask=mask_np,
            metrics={k: float(v) for k, v in res.metrics.items()},
            alloc=exp.alloc, timing=exp.timing,
            round_time=round_time, cumulative_time=cumulative, eta=exp.eta,
            events=plan.events, staleness=plan.staleness,
            completion=plan.completion)
        records.append(rec)
        if on_round is not None:
            on_round(rec)

        if ckpt is not None and checkpoint_every and (r + 1) % checkpoint_every == 0:
            _save(ckpt, exp, r + 1, cumulative, campaign_seed, reallocate)

    if ckpt is not None and target > start:
        saved_on_loop = checkpoint_every and target % checkpoint_every == 0
        if not saved_on_loop:
            _save(ckpt, exp, target, cumulative, campaign_seed, reallocate)

    exp.campaign_time = cumulative
    return CampaignResult(records=records, state=exp.state,
                          total_time=cumulative, rounds_lemma1=rounds_lemma1,
                          stopped_by=stopped_by, scenario=scenario.name,
                          topology=exp.topology.name,
                          schedule=exp.schedule.name,
                          population=exp.population.name)


def _save(ckpt: Checkpointer, exp: "Experiment", rounds_done: int,
          cumulative: float, campaign_seed: int, reallocate: bool) -> None:
    # stateful local algorithms (scaffold) must resume with the exact
    # variates the interrupted campaign carried, so they ride the payload
    payload = (exp.state if exp.algo_state is None
               else {"model": exp.state, "algo_state": exp.algo_state})
    ckpt.save(rounds_done, payload,
              {"round": rounds_done, "cumulative_time": cumulative,
               "campaign_seed": campaign_seed, "eta": exp.eta,
               "allocator": exp.allocator_name,
               "scenario": exp.scenario.name,
               "ls_digest": exp.scenario.digest(exp.fcfg, campaign_seed),
               "topology": exp.topology.name,
               "topo_digest": exp.topology.digest(exp.fcfg, exp.scenario,
                                                  campaign_seed),
               "schedule": exp.schedule.name,
               "schedule_params": repr(sorted(exp.schedule.params().items())),
               "local_algo": exp.local_algo.name,
               "local_algo_params": repr(sorted(exp.local_algo.params().items())),
               "workload": exp.workload.name,
               "workload_params": repr(sorted(exp.workload.params().items())),
               "population": exp.population.name,
               "population_params":
                   repr(sorted(exp.population.params().items())),
               "reallocate": reallocate})
