"""Client-population models (the 9th pluggable strategy axis).

``exact`` (default, bit-identical) | ``compact`` (O(cohort) device batches)
| ``meanfield`` (O(cohort) timelines + analytic queues) — see
``repro.pop.population`` for the axis contract and
``repro.pop.meanfield`` for the mean-field validity regime.
"""

from repro.pop.meanfield import (MeanFieldPopulation, meanfield_backhaul_hop,
                                 REP_STREAM_TAG)
from repro.pop.population import (CompactPopulation, ExactPopulation,
                                  Population, get_population, populations)

__all__ = [
    "Population", "ExactPopulation", "CompactPopulation",
    "MeanFieldPopulation", "get_population", "populations",
    "meanfield_backhaul_hop", "REP_STREAM_TAG",
]
