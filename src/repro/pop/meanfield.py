"""Mean-field population: O(cohort) timelines and analytic queue pricing.

``meanfield`` extends ``compact`` (same fixed-window device compaction)
with three host-side reductions, so a 10⁵-client campaign's per-round cost
stops scaling with K everywhere, not just on the device:

  * **representative timeline** — only a seeded, campaign-fixed set of C
    representative clients launches in the discrete-event timeline
    (``AsyncSchedule.planner`` restricts its launch set through
    ``timeline_clients()``), so the event heap holds O(C) entries instead
    of O(K);
  * **analytic queue pricing** — the FIFO/PS shared-backhaul hop is priced
    by :func:`meanfield_backhaul_hop` instead of the exact per-job queue
    simulation (``HierTopology._queued_backhaul`` — an O(K) python loop for
    FIFO, O(K²)-ish fluid stepping for PS): the K−C non-representative
    clients are modelled as per-cell arrival-rate processes feeding the
    shared queue, and each job's wait comes from the validated analytic
    M/D/1 (``queueing.md1_mean_wait``) / PS (``queueing.ps_mean_wait``)
    references, capped at the all-at-once batch backlog;
  * **representative allocation** — under ``reallocate=True`` each edge
    cell's (16)/(17) solve runs on its representative members only, with
    the cell bandwidth pool scaled by the representative fraction
    (population multiplicities), and every non-representative member adopts
    its nearest representative's bandwidth share re-timed at its own gains
    (``repro.net.allocation._solve_cell``).

**Validity regime.**  The mean-field queue model is accurate when (a) the
per-round backhaul utilisation ρ = λ·s̄ is below ~1 over each cell's
arrival span — above it the analytic wait is capped at the batch backlog
((n−1)·s̄/2 for FIFO, (n−1)·s̄ for PS), which is exact for a simultaneous
equal-service batch — and (b) the cohort fraction C/K is small enough that
the representatives' own queue contribution is marginal (the regime the
subsystem exists for).  Both are validated in ``tests/test_pop.py``:
``test_meanfield_waits_match_exact_des_within_10pct`` checks the mean hop
against the full exact DES at a K where both run, and
``test_meanfield_matches_md1_poisson`` checks the arrival-rate summation
against the analytic M/D/1 reference on Poisson arrivals.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import federated
from repro.des import queueing
from repro.pop.population import CompactPopulation, populations

# Tag added to the campaign seed for the representative-client draw — a
# distinct stream from cohort sampling (0x5EED) and channel fades (7919),
# same idiom as repro.sim.events.
REP_STREAM_TAG = 0xAB5E


def meanfield_backhaul_hop(topology, fcfg, assign, eta,
                           totals: np.ndarray) -> np.ndarray:
    """(K,) analytic backhaul hop under per-cell arrival-rate processes.

    Each cell's jobs (``topology._backhaul_jobs`` — per client for
    edge-cloud/relay, one pre-aggregated delta per edge for edge-agg) are
    modelled as an arrival-rate process over that cell's own completion
    span; the shared queue sees the aggregate rate λ = Σ_m n_m/span_m.
    The mean wait is the analytic M/D/1 (FIFO) / PS model at (λ, s̄),
    capped at the all-at-once batch backlog — (n−1)·s̄/2 for FIFO (the
    exact mean of a simultaneous equal-service batch), (n−1)·s̄ for PS
    (every job of a simultaneous PS batch finishes together at n·s̄).
    FIFO waits ramp linearly in arrival rank (later arrivals expect
    proportionally more backlog, matching ``allocation``'s wait-aware
    model); PS waits are rank-independent (the egalitarian discipline).
    Clients whose wireless total is non-finite never reach the queue and
    get hop 0, exactly like ``HierTopology._queued_backhaul``.
    """
    totals = np.asarray(totals, float)
    arrivals, bits, job_of = topology._backhaul_jobs(fcfg, assign, eta,
                                                     totals)
    service = queueing.service_seconds(bits, topology.backhaul_bps)
    finite = np.isfinite(arrivals)
    n = int(np.count_nonzero(finite))
    hop_jobs = np.zeros(len(arrivals))
    if n:
        s_bar = float(np.mean(service[finite]))
        if n > 1 and s_bar > 0:
            # the cell each job came from (per-client jobs: the client's
            # cell; per-edge jobs: the edge itself)
            job_cell = np.zeros(len(arrivals), int)
            job_cell[job_of] = np.asarray(assign, int)
            lam, singles = 0.0, 0
            for m in np.unique(job_cell[finite]):
                sel = finite & (job_cell == m)
                nm = int(np.count_nonzero(sel))
                if nm < 2:
                    singles += nm
                    continue
                span = float(np.max(arrivals[sel]) - np.min(arrivals[sel]))
                if span > 0:
                    lam += nm / span
                else:
                    lam = np.inf  # a simultaneous burst saturates the rate
            if singles and np.isfinite(lam):
                gspan = float(np.max(arrivals[finite])
                              - np.min(arrivals[finite]))
                lam += singles / gspan if gspan > 0 else np.inf
            if topology.backhaul_model == "ps":
                mean_wait = (queueing.ps_mean_wait(lam, s_bar)
                             if np.isfinite(lam) else np.inf)
                wait = np.full(n, min(mean_wait, (n - 1) * s_bar))
            else:  # fifo
                mean_wait = (queueing.md1_mean_wait(lam, s_bar)
                             if np.isfinite(lam) else np.inf)
                mean_wait = min(mean_wait, 0.5 * (n - 1) * s_bar)
                ranks = np.empty(n)
                ranks[np.argsort(arrivals[finite],
                                 kind="stable")] = np.arange(n)
                wait = mean_wait * 2.0 * ranks / (n - 1)
            hop_jobs[finite] = wait + service[finite]
        else:
            hop_jobs[finite] = service[finite]
    hop = hop_jobs[job_of]
    hop[~np.isfinite(totals)] = 0.0
    return hop


@populations.register("meanfield")
class MeanFieldPopulation(CompactPopulation):
    """``compact`` + representative timeline + analytic queues (see the
    module docstring for the three reductions and the validity regime).

    ``window`` sizes the device batch (default: the campaign cohort);
    ``reps`` sizes the representative set the timeline and the per-cell
    allocator run on (default: the window).  ``reps ≥ K`` degenerates the
    timeline and allocation back to exact (only the analytic queue pricing
    remains).
    """

    name = "meanfield"

    def __init__(self, window: Optional[int] = None,
                 reps: Optional[int] = None):
        super().__init__(window=window)
        if reps is not None and reps < 1:
            raise ValueError(f"reps must be ≥ 1, got {reps}")
        self.reps = None if reps is None else int(reps)
        self.rep_ids: Optional[np.ndarray] = None  # bound by begin_campaign

    def params(self) -> dict:
        return {"window": self.window, "reps": self.reps}

    def begin_campaign(self, num_clients: int, cohort: int,
                       campaign_seed: int) -> None:
        super().begin_campaign(num_clients, cohort, campaign_seed)
        n_rep = self.reps if self.reps is not None else self._window
        n_rep = min(max(int(n_rep), self._window), num_clients)
        if n_rep >= num_clients:
            self.rep_ids = None  # full population: exact timeline
        else:
            # seeded, campaign-fixed representative draw — rides the same
            # O(cohort) client_sample as cohorts, on its own stream
            self.rep_ids = federated.client_sample(
                0, num_clients, n_rep, seed=campaign_seed + REP_STREAM_TAG)
            self._pool = self.rep_ids  # window fill stays inside the reps

    def timeline_clients(self) -> Optional[np.ndarray]:
        return self.rep_ids

    def queued_hop(self, topology, fcfg, assign, eta,
                   totals) -> Optional[np.ndarray]:
        return meanfield_backhaul_hop(topology, fcfg, assign, eta, totals)
