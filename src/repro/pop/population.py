"""Client-population models — the 9th pluggable strategy axis.

Every execution schedule used to push the FULL simulated population through
the jitted round function each aggregation (the async family literally sets
``client_ids = arange(K)`` and lets the mask pick the arrivals), and the
discrete-event timeline launched all K clients.  Both are O(K) per round —
fine at the paper's K=8–50, impossible at the ROADMAP's 10⁴–10⁶ target.
This module makes the *population model* a first-class :class:`Population`,
registered by name like the other eight axes (aggregators / allocators /
compressors / scenarios / topologies / schedules / local_algos / workloads):

  ``exact``      every client is simulated and trained individually — the
                 default, bit-identical to the pre-population engine (every
                 campaign golden pins this path)
  ``compact``    compacted cohorts: each aggregation's arrivals plus a
                 fixed-size in-flight window are gathered into a dense
                 ``(C, …)`` batch, so the round function is traced once at
                 shape ``(C, …)`` and per-round device FLOPs/memory stop
                 scaling with K.  The gather/scatter of per-client algorithm
                 state rides the round function's existing ``algo_ids``
                 in-trace ``x[ids]`` / ``at[ids].set`` mechanism (SCAFFOLD's
                 variates), global ``D_k`` weights ride ``client_ids``, and
                 the window batch is C-sharded over the device mesh via
                 ``parallel.sharding``'s ``"batch"`` logical axis.  The
                 timeline and queue pricing stay exact (still O(K) host
                 work per round).
  ``meanfield``  ``compact`` plus a mean-field DES: only a seeded set of C
                 *representative* clients runs in the discrete-event
                 timeline, the other K−C clients become per-cell
                 arrival-rate processes feeding the FIFO/PS backhaul queues
                 analytically, and per-cell rate allocation solves on the
                 representatives with population multiplicities
                 (``repro.pop.meanfield`` — validity regime and validation
                 tests in its module docstring).  Campaign cost becomes
                 O(cohort) end to end.

A population owns five hooks, every one a no-op on ``exact`` so the default
path stays byte-for-byte untouched:

  * ``begin_campaign(K, cohort, seed)`` — bind per-campaign state (window
    size, representative set); re-bound on every ``run()`` so campaigns
    stay pure in ``(RunConfig, seed)`` and resume replays identically;
  * ``compact_plan(plan, ids, round)`` — compact a K-sized async
    :class:`~repro.des.schedules.RoundPlan` onto the fixed window;
  * ``timeline_clients()`` — restrict the event timeline's launch set;
  * ``queued_hop(topology, …)`` — replace the exact queue simulation with
    an analytic arrival-rate model (``meanfield`` only);
  * ``device_batch(batches)`` — shard the ``(C, …)`` window batch over the
    mesh's batch axis.

The population name + params join the checkpoint identity guard (the same
family as scenario/topology/schedule digests): resume refuses a
population-name or window-size mismatch.

    exp = Experiment.from_config(run_cfg, schedule="async",
                                 population="compact")
    exp.run(num_rounds=20, stream=stream, cohort=8)   # (8, …) traces

Unknown names raise ``KeyError`` listing the knowns, like every registry.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import numpy as np

from repro.parallel.sharding import shard
from repro.registry import Registry

populations: Registry = Registry("population")


class Population:
    """Base class: how the K simulated clients map onto simulated work.

    All methods must be pure in their arguments plus the state bound by
    ``begin_campaign`` — determinism in ``(seed, round)`` is part of the
    registry contract, and checkpoint resume relies on a re-bound
    population reproducing the interrupted campaign's windows exactly.
    """

    name = "population"

    def params(self) -> dict:
        """Constructor parameters that change the model (checkpoint guard)."""
        return {}

    def begin_campaign(self, num_clients: int, cohort: int,
                       campaign_seed: int) -> None:
        """Bind per-campaign state; called at the top of every ``run()``."""

    def compact_plan(self, plan, ids: np.ndarray,
                     round_idx: int) -> tuple:
        """Compact one round's plan + cohort ids; identity for ``exact``."""
        return plan, ids

    def timeline_clients(self) -> Optional[np.ndarray]:
        """Clients the event timeline launches; None = the full population."""
        return None

    def queued_hop(self, topology, fcfg, assign, eta,
                   totals) -> Optional[np.ndarray]:
        """(K,) analytic backhaul hop, or None to run the exact queue sim."""
        return None

    def device_batch(self, batches):
        """Place/shard the stacked per-round batch; identity for ``exact``."""
        return batches

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"{type(self).__name__}({self.name!r})"


@populations.register("exact")
class ExactPopulation(Population):
    """Every client simulated and trained individually — the default,
    bit-identical to the pre-population engine (every hook is the
    identity, so nothing downstream can tell this axis exists)."""

    name = "exact"


@populations.register("compact")
class CompactPopulation(Population):
    """Compacted cohorts: O(cohort) device cost under async schedules.

    Each aggregation gathers its arrivals plus enough in-flight clients to
    fill a FIXED-size window of ``window`` clients (default: the campaign
    cohort) into a dense ``(C, …)`` batch.  Non-arrival window members ride
    along fully masked (a masked client contributes exactly +0.0 to the
    weighted-mean sums), so the aggregation equals the exact K-sized round
    up to float summation order — and the round function keeps ONE trace at
    shape ``(C, …)``: ``trace_count`` bounds are unchanged (asserted in
    ``tests/test_pop.py``).  The window fill rotates deterministically
    through the population keyed by round index, so per-client algorithm
    state (SCAFFOLD variates, gathered/scattered in-trace via ``algo_ids``)
    keeps refreshing across the whole population.

    Sync-family plans (``plan.client_ids is None``) are already
    cohort-sized and pass through untouched; a window of at least the full
    population degenerates to ``exact``.
    """

    name = "compact"

    def __init__(self, window: Optional[int] = None):
        if window is not None and window < 1:
            raise ValueError(f"window must be ≥ 1, got {window}")
        self.window = None if window is None else int(window)
        self._window: Optional[int] = None  # bound by begin_campaign
        self._pool: Optional[np.ndarray] = None

    def params(self) -> dict:
        return {"window": self.window}

    def begin_campaign(self, num_clients: int, cohort: int,
                       campaign_seed: int) -> None:
        self._window = min(self.window if self.window is not None else cohort,
                           num_clients)
        self._pool = np.arange(num_clients)

    def compact_plan(self, plan, ids: np.ndarray, round_idx: int) -> tuple:
        if plan.client_ids is None or plan.mask is None:
            return plan, ids  # sync family: already cohort-sized
        K = len(plan.client_ids)
        if self._window is None or self._window >= K:
            return plan, ids  # unbound, or window covers the population
        pool = self._pool if self._pool is not None else np.arange(K)
        want = min(self._window, len(pool))
        arrivals = np.where(np.asarray(plan.mask) > 0)[0]
        if len(arrivals) > want:
            raise ValueError(
                f"population {self.name!r} window={want} cannot hold the "
                f"{len(arrivals)} arrivals of round {round_idx} — raise "
                f"window= (or cohort=) to at least the schedule's buffer_k")
        # deterministic rotating fill: arrivals first, then pool members
        # starting at a round-keyed offset, so the fixed-size window sweeps
        # the whole population across rounds (pure in round_idx — resume
        # replays the identical windows)
        sel = set(int(a) for a in arrivals)
        start = (round_idx * want) % len(pool)
        i = 0
        while len(sel) < want and i < len(pool):
            sel.add(int(pool[(start + i) % len(pool)]))
            i += 1
        window = np.sort(np.fromiter(sel, np.int64, count=len(sel)))
        take = lambda a: None if a is None else np.asarray(a)[window]  # noqa: E731
        plan = dataclasses.replace(
            plan, client_ids=window, mask=take(plan.mask),
            weight_scale=take(plan.weight_scale),
            staleness=take(plan.staleness),
            completion=take(plan.completion))
        return plan, window

    def device_batch(self, batches):
        # C-shard the window batch over the mesh's batch axis ("pod","data"
        # under the train rule-set); a no-op outside a sharding context
        return jax.tree.map(
            lambda x: shard(x, ("batch",) + (None,) * (x.ndim - 1)), batches)


def get_population(spec: Union[str, Population]) -> Population:
    """Resolve a population name or pass an instance through.

    ``get_population("compact")`` → the registered default instance;
    ``get_population(CompactPopulation(window=16))`` → the object itself.
    Unknown names raise ``KeyError`` listing the registered names.
    """
    if isinstance(spec, Population):
        return spec
    if isinstance(spec, type) and issubclass(spec, Population):
        return spec()
    cls = populations.get(spec)
    return cls()
