"""Privacy mechanisms for the FedsLLM uplink.

The paper's Fig. 1 includes a client-side *noise layer* on the smashed
activations and its delay model explicitly assumes "no privacy protection
measures such as noise layers or differential privacy" when pricing the
round — i.e. privacy is part of the framework but priced out of §III.  This
module supplies both mechanisms so the framework is deployable where the
assumption doesn't hold:

  * ``clip_and_noise_updates`` — central/local DP for the fed-server upload
    (per-client L2 clipping + Gaussian mechanism, Abadi et al. 2016): the
    fed server aggregates   mean_k clip(h_k, c) + N(0, σ²c²/K).
  * ``noise_layer`` — the paper's smashed-activation noise (additive
    Gaussian at the split boundary, scaled to the activation RMS).
  * ``privacy_cost`` — (ε, δ) accounting for the Gaussian mechanism across
    rounds (simple composition; a production deployment would swap in RDP).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim.grad_utils import global_norm


def clip_tree(tree, clip_norm: float):
    """Per-client L2 clip: h ← h · min(1, c/‖h‖)."""
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree)


def clip_and_noise_updates(stacked, key, *, clip_norm: float = 1.0,
                           noise_multiplier: float = 0.0):
    """DP-FedAvg preprocessing on stacked (K, ...) client updates.

    Clips every client's update to ``clip_norm`` and adds Gaussian noise
    N(0, (σ·c)²) to the SUM (so the mean sees σ·c/K — standard DP-FedAvg).
    Returns the processed stacked tree (aggregate with federated.fedavg)."""
    K = jax.tree.leaves(stacked)[0].shape[0]
    clipped = jax.vmap(lambda t: clip_tree(t, clip_norm))(stacked)
    if noise_multiplier <= 0.0:
        return clipped
    leaves, treedef = jax.tree_util.tree_flatten(clipped)
    keys = jax.random.split(key, len(leaves))
    noisy = []
    std = noise_multiplier * clip_norm  # noise on the sum
    for leaf, k in zip(leaves, keys):
        # add to client 0's slot: mean_k(x) + N(0, (σc)²)/K == fedavg(noisy)
        n = jax.random.normal(k, leaf.shape[1:], jnp.float32) * std
        noisy.append(leaf.at[0].add(n.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, noisy)


def noise_layer(acts: jax.Array, key, *, snr_db: float = 20.0) -> jax.Array:
    """The paper's client-side noise layer on smashed activations: additive
    Gaussian scaled to the activation RMS at the given SNR."""
    rms = jnp.sqrt(jnp.mean(jnp.square(acts.astype(jnp.float32))) + 1e-12)
    sigma = rms * (10.0 ** (-snr_db / 20.0))
    return acts + (sigma * jax.random.normal(key, acts.shape, jnp.float32)).astype(acts.dtype)


def privacy_cost(noise_multiplier: float, rounds: int, sample_rate: float = 1.0,
                 delta: float = 1e-5) -> float:
    """ε for ``rounds`` Gaussian-mechanism releases (advanced composition
    upper bound; conservative)."""
    if noise_multiplier <= 0:
        return math.inf
    eps_step = sample_rate * math.sqrt(2.0 * math.log(1.25 / delta)) / noise_multiplier
    return eps_step * math.sqrt(2.0 * rounds * math.log(1.0 / delta)) + \
        rounds * eps_step * (math.exp(eps_step) - 1.0)
