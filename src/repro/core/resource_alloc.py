"""Delay-minimisation resource allocation (paper §III-D/E, problems (16)/(17)).

The paper reduces (16) to the convex problem (17) by fixing f*=f_max,
p*=p_max, A*=A_min, then sweeps η ∈ (0,1) in 0.01 steps solving (17) with
MATLAB fmincon (interior point).  We provide:

  * ``solve_fixed_eta_exact``  — beyond-paper exact structured solver:
      outer bisection on T; inner λ-weighted bandwidth balancing with
      per-user 1-D convex splits (vectorised golden section).  Exploits
      Lemma 3 (time budgets tight, rate constraints tight at optimum);
      ~10³× faster than the NLP route with the same optimum.
  * ``solve_fixed_eta_scipy``  — the faithful fmincon-equivalent (SLSQP on
      the full (T, t_c, t_s, b_c, b_s) program), used as the paper-faithful
      baseline and as a cross-check.
  * ``optimize``               — the η sweep + the paper's comparison
      strategies: 'proposed', 'EB' (equal bandwidth, optimise η),
      'FE' (fix η=0.1, optimise bandwidth), 'BA' (both fixed).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.config import FedsLLMConfig
from repro.core import delay_model as dm

GOLD = (np.sqrt(5.0) - 1.0) / 2.0


@dataclass
class Allocation:
    T: float
    eta: float
    A: float
    t_c: np.ndarray
    t_s: np.ndarray
    b_c: np.ndarray
    b_s: np.ndarray
    feasible: bool
    strategy: str = "proposed"


# ---------------------------------------------------------------------------
# Inner problem: given T and η, can the bandwidth budgets support it?
# ---------------------------------------------------------------------------


def _split_costs(theta, R, V, s_c, s, net: dm.Network):
    """Bandwidths required for split θ (vectorised over K).

    t_c = θ·R;  t_s = (1-θ)·R/V  (budget tight — Lemma 3)."""
    t_c = np.maximum(theta * R, 1e-12)
    t_s = np.maximum((1.0 - theta) * R / V, 1e-12)
    b_c = dm.bandwidth_for_rate(s_c / t_c, net.g_c, net.p_c_max, net.N0)
    b_s = dm.bandwidth_for_rate(s / t_s, net.g_s, net.p_s_max, net.N0)
    return t_c, t_s, b_c, b_s


def _best_split(lmbda, R, V, s_c, s, net: dm.Network, iters: int = 32):
    """Per-user golden-section over θ for weighted cost
    λ·b_c/B_c + (1-λ)·b_s/B_s (convex in θ). Vectorised over users."""
    lo = np.full_like(R, 1e-6)
    hi = np.full_like(R, 1.0 - 1e-6)

    def cost(theta):
        _, _, b_c, b_s = _split_costs(theta, R, V, s_c, s, net)
        return lmbda * b_c / net.B_c + (1.0 - lmbda) * b_s / net.B_s

    for _ in range(iters):
        x1 = hi - GOLD * (hi - lo)
        x2 = lo + GOLD * (hi - lo)
        go_right = cost(x1) > cost(x2)
        lo = np.where(go_right, x1, lo)
        hi = np.where(go_right, hi, x2)
    theta = 0.5 * (lo + hi)
    return theta


def _feasibility(T, cfg: FedsLLMConfig, net: dm.Network, eta: float, A: float,
                 model_params, lam_iters: int = 12, extra_delay=None):
    """min over λ of max(Σb_c/B_c, Σb_s/B_s) at latency target T.

    ``extra_delay`` is an optional (K,) per-user fixed latency already
    committed outside the wireless hop (the wait-aware allocator's expected
    backhaul wait+service): it tightens each user's budget exactly like the
    compute time does, ``R = T/I0 − τ − extra``.  ``None`` keeps the
    legacy wireless-only budget bit-identical.
    """
    I0 = dm.global_rounds(cfg, eta)
    V = dm.local_iters(cfg, eta)
    tau = dm.compute_time(cfg, net, eta, A, model_params)
    if extra_delay is not None:
        tau = tau + np.asarray(extra_delay, float)
    R = T / I0 - tau
    if np.any(R <= 0):
        return np.inf, None
    s_c, s = cfg.s_c_bits, cfg.s_bits

    def usage(lmbda):
        theta = _best_split(lmbda, R, V, s_c, s, net)
        t_c, t_s, b_c, b_s = _split_costs(theta, R, V, s_c, s, net)
        return np.sum(b_c) / net.B_c, np.sum(b_s) / net.B_s, (t_c, t_s, b_c, b_s)

    lo, hi = 0.0, 1.0
    best = None
    best_val = np.inf
    for _ in range(lam_iters):
        mid = 0.5 * (lo + hi)
        u_c, u_s, alloc = usage(mid)
        val = max(u_c, u_s)
        if val < best_val:
            best_val, best = val, alloc
        # raise weight on the busier budget
        if u_c > u_s:
            lo = mid
        else:
            hi = mid
    return best_val, best


def solve_fixed_eta_exact(cfg: FedsLLMConfig, net: dm.Network, eta: float,
                          A: Optional[float] = None, model_params=None,
                          T_hi: Optional[float] = None, iters: int = 30,
                          extra_delay=None) -> Allocation:
    """Bisection on T; inner bandwidth-balancing feasibility (exact).

    ``extra_delay`` (optional (K,)) shrinks each user's per-round budget by
    a fixed latency committed elsewhere on its path — the wait-aware
    allocator's expected backhaul term; ``None`` is the legacy
    wireless-only problem, bit-identical.
    """
    A = cfg.split_ratio_min if A is None else A  # paper: A* = A_min
    I0 = dm.global_rounds(cfg, eta)
    tau = dm.compute_time(cfg, net, eta, A, model_params)
    if extra_delay is not None:
        tau = tau + np.asarray(extra_delay, float)
    T_lo = I0 * np.max(tau)
    if T_hi is None:
        eb = solve_equal_bandwidth(cfg, net, eta, A, model_params)
        T_hi = eb.T * 1.001 if np.isfinite(eb.T) else I0 * np.max(tau) * 1e4 + 1e3
    # ensure hi feasible
    val, alloc = _feasibility(T_hi, cfg, net, eta, A, model_params,
                              extra_delay=extra_delay)
    grow = 0
    while val > 1.0 and grow < 40:
        T_hi *= 2.0
        val, alloc = _feasibility(T_hi, cfg, net, eta, A, model_params,
                                  extra_delay=extra_delay)
        grow += 1
    if val > 1.0:
        return Allocation(np.inf, eta, A, None, None, None, None, False)
    for _ in range(iters):
        if T_hi - T_lo < 1e-5 * T_hi:
            break
        mid = 0.5 * (T_lo + T_hi)
        val, a = _feasibility(mid, cfg, net, eta, A, model_params,
                              extra_delay=extra_delay)
        if val <= 1.0:
            T_hi, alloc = mid, a
        else:
            T_lo = mid
    t_c, t_s, b_c, b_s = alloc
    return Allocation(T_hi, eta, A, t_c, t_s, b_c, b_s, True)


# ---------------------------------------------------------------------------
# Equal-bandwidth closed form (EB / BA baselines)
# ---------------------------------------------------------------------------


def solve_equal_bandwidth(cfg: FedsLLMConfig, net: dm.Network, eta: float,
                          A: Optional[float] = None, model_params=None) -> Allocation:
    A = cfg.split_ratio_min if A is None else A
    K = net.K
    b_c = np.full(K, net.B_c / K)
    b_s = np.full(K, net.B_s / K)
    r_c = dm.rate(b_c, net.g_c, net.p_c_max, net.N0)
    r_s = dm.rate(b_s, net.g_s, net.p_s_max, net.N0)
    t_c = cfg.s_c_bits / r_c
    t_s = cfg.s_bits / r_s
    T_k = dm.round_latency(cfg, net, eta, A, t_c, t_s, model_params)
    return Allocation(float(np.max(T_k)), eta, A, t_c, t_s, b_c, b_s, True, "EB")


# ---------------------------------------------------------------------------
# Faithful NLP solver (fmincon interior-point equivalent)
# ---------------------------------------------------------------------------


def solve_fixed_eta_scipy(cfg: FedsLLMConfig, net: dm.Network, eta: float,
                          A: Optional[float] = None, model_params=None,
                          x0: Optional[np.ndarray] = None,
                          extra_delay=None) -> Allocation:
    """Problem (17) as stated: vars x = [T, t_c(K), t_s(K), b_c(K), b_s(K)]."""
    from scipy.optimize import NonlinearConstraint, LinearConstraint, minimize

    A = cfg.split_ratio_min if A is None else A
    K = net.K
    I0 = dm.global_rounds(cfg, eta)
    V = dm.local_iters(cfg, eta)
    tau = dm.compute_time(cfg, net, eta, A, model_params)
    if extra_delay is not None:
        tau = tau + np.asarray(extra_delay, float)
    s_c, s = cfg.s_c_bits, cfg.s_bits

    def unpack(x):
        return x[0], x[1:1 + K], x[1 + K:1 + 2 * K], x[1 + 2 * K:1 + 3 * K], x[1 + 3 * K:]

    def f_obj(x):
        return x[0]

    def g_latency(x):  # T/I0 - tau - t_c - V t_s >= 0
        T, t_c, t_s, _, _ = unpack(x)
        return T / I0 - tau - t_c - V * t_s

    def g_rate_s(x):  # t_s * r(b_s) - s >= 0
        _, _, t_s, _, b_s = unpack(x)
        return t_s * dm.rate(b_s, net.g_s, net.p_s_max, net.N0) - s

    def g_rate_c(x):
        _, t_c, _, b_c, _ = unpack(x)
        return t_c * dm.rate(b_c, net.g_c, net.p_c_max, net.N0) - s_c

    def g_bw(x):
        _, _, _, b_c, b_s = unpack(x)
        return np.array([net.B_c - np.sum(b_c), net.B_s - np.sum(b_s)])

    if x0 is None:
        eb = solve_equal_bandwidth(cfg, net, eta, A, model_params)
        x0 = np.concatenate([[eb.T * 1.05], eb.t_c * 1.05, eb.t_s * 1.05, eb.b_c, eb.b_s])

    cons = [
        {"type": "ineq", "fun": g_latency},
        {"type": "ineq", "fun": g_rate_s},
        {"type": "ineq", "fun": g_rate_c},
        {"type": "ineq", "fun": g_bw},
    ]
    bounds = [(0.0, None)] * (1 + 4 * K)
    res = minimize(f_obj, x0, method="SLSQP", constraints=cons, bounds=bounds,
                   options={"maxiter": 400, "ftol": 1e-10})
    T, t_c, t_s, b_c, b_s = unpack(res.x)
    return Allocation(float(T), eta, A, t_c, t_s, b_c, b_s, bool(res.success), "scipy")


# ---------------------------------------------------------------------------
# η sweep + comparison strategies (paper §IV)
# ---------------------------------------------------------------------------


def quantize_eta(eta: float, bucket: float = 0.05,
                 eta_max: float = 0.5) -> float:
    """Snap a solved η* onto the training-η bucket grid, clamped to
    [bucket, eta_max].

    The jitted round function's trace depends on η through Lemma 2's local
    iteration count, so a campaign that adopts every round's exact η* would
    recompile every round.  Quantizing to a coarse grid bounds the number of
    distinct traces by the number of buckets (``Experiment.set_eta``).
    """
    if bucket <= 0:
        raise ValueError(f"eta bucket must be positive, got {bucket}")
    q = round(round(float(eta) / bucket) * bucket, 10)
    return float(np.clip(q, bucket, eta_max))


def eta_grid_for(cfg: FedsLLMConfig, eta_search: str = "grid",
                 eta0: Optional[float] = None) -> np.ndarray:
    """The η candidate grid an ``eta_search`` mode sweeps.

    Shared by :func:`optimize` and the hierarchical per-cell optimiser
    (``repro.net.allocation``) so both sweep byte-identical grids: 'grid' is
    the paper-faithful 0.01 step, 'coarse' a 0.05 step (refined locally by
    the caller), 'warm' a ±5·eta_step window around ``eta0``.
    """
    if eta_search == "warm":
        if eta0 is None:
            raise ValueError("eta_search='warm' requires eta0= "
                             "(the anchor of the local window)")
        step = cfg.eta_step
        lo = max(step, eta0 - 5.0 * step)
        hi = min(1.0 - step, eta0 + 5.0 * step)
        return np.arange(lo, hi + step / 2.0, step)
    if eta_search == "coarse":
        return np.arange(0.05, 1.0, 0.05)
    return np.arange(cfg.eta_step, 1.0, cfg.eta_step)


def eta_refine_grid(cfg: FedsLLMConfig, eta: float) -> np.ndarray:
    """The local ``eta_step``-step window the 'coarse' mode refines around
    its sweep argmin — shared by :func:`optimize` and the per-cell optimiser
    so both refine byte-identical grids."""
    step = cfg.eta_step
    lo = max(step, eta - 0.05)
    hi = min(1.0 - step, eta + 0.05)
    return np.arange(lo, hi + step / 2.0, step)


def optimize(cfg: FedsLLMConfig, net: dm.Network, strategy: str = "proposed",
             model_params=None, eta_grid: Optional[np.ndarray] = None,
             solver: str = "exact", eta_search: str = "grid",
             eta0: Optional[float] = None,
             extra_delay: Optional[np.ndarray] = None) -> Allocation:
    """Full optimiser.  strategy ∈ {proposed, EB, FE, BA}.

    ``extra_delay`` — optional (K,) fixed per-user latency committed outside
    the wireless hop (the wait-aware allocator's expected backhaul
    wait+service); only the 'proposed' solver responds to it (the EB/FE/BA
    baselines stay wait-blind by design).

    eta_search='grid' is the paper-faithful 0.01-step sweep; 'coarse' runs a
    0.05-step sweep + one 0.01-step local refinement around the argmin
    (identical optimum on smooth T(η), ~6× fewer solves — used by the
    benchmark harness); 'warm' sweeps only a ±5·eta_step window around a
    previously solved ``eta0`` (the per-round joint re-solve of the campaign
    engine: block fading moves T(η) but barely moves its argmin, so a local
    window finds the same optimum ~10× cheaper — and, unlike warm-starting
    from the *previous round's* solve, stays a pure function of the round,
    which checkpoint resume requires)."""
    if eta_grid is None:
        eta_grid = eta_grid_for(cfg, eta_search, eta0)
    fixed_eta = 0.1  # paper: FE/BA fix η = 0.1

    if strategy == "BA":
        return solve_equal_bandwidth(cfg, net, fixed_eta, model_params=model_params)
    if strategy == "FE":
        fn = solve_fixed_eta_exact if solver == "exact" else solve_fixed_eta_scipy
        a = fn(cfg, net, fixed_eta, model_params=model_params)
        return dataclasses.replace(a, strategy="FE")
    if strategy == "EB":
        best = None
        for eta in eta_grid:
            a = solve_equal_bandwidth(cfg, net, float(eta), model_params=model_params)
            if best is None or a.T < best.T:
                best = a
        return dataclasses.replace(best, strategy="EB")
    if strategy == "proposed":
        fn = solve_fixed_eta_exact if solver == "exact" else solve_fixed_eta_scipy
        best = None
        for eta in eta_grid:
            eta = float(eta)
            if solver == "exact" and best is not None:
                # prune: if the incumbent T* is infeasible at this η, this η
                # cannot improve on it (T(η) would exceed T*) — one cheap check
                val, _ = _feasibility(best.T, cfg, net, eta, cfg.split_ratio_min,
                                      model_params, extra_delay=extra_delay)
                if val > 1.0:
                    continue
                a = fn(cfg, net, eta, model_params=model_params,
                       T_hi=best.T * 1.0001, extra_delay=extra_delay)
            else:
                a = fn(cfg, net, eta, model_params=model_params,
                       extra_delay=extra_delay)
            if a.feasible and (best is None or a.T < best.T):
                best = a
        if eta_search == "coarse" and best is not None:
            for eta in eta_refine_grid(cfg, best.eta):
                eta = float(eta)
                val, _ = _feasibility(best.T, cfg, net, eta, cfg.split_ratio_min,
                                      model_params, extra_delay=extra_delay)
                if val > 1.0:
                    continue
                a = fn(cfg, net, eta, model_params=model_params,
                       T_hi=best.T * 1.0001, extra_delay=extra_delay)
                if a.feasible and a.T < best.T:
                    best = a
        return dataclasses.replace(best, strategy="proposed")
    raise ValueError(strategy)
