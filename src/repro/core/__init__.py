from repro.core import compression, delay_model, federated, fedsllm, lora, resource_alloc, split

__all__ = [
    "compression",
    "delay_model",
    "federated",
    "fedsllm",
    "lora",
    "resource_alloc",
    "split",
]
