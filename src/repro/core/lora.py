"""LoRA (paper eq. (1)): w0 + Δw = w0 + B·A, with B ∈ R^{d×r}, A ∈ R^{r×k},
r << min(d, k).

The *frozen* base params stay untouched; the trainable tree mirrors the base
tree at the targeted projection leaves with {"A": (..., d_in, r),
"B": (..., r, d_out)} factor pairs (leading stacked-layer / expert dims are
preserved, so one declaration covers dense, scanned and MoE weights).

Two application modes:
  * ``merge``      — W' = W + (α/r)·A@B, used by the training path (autodiff
                     through the merge yields exact dA/dB); cheap under remat.
  * fused kernel   — y = x·W + (α/r)·(x·A)·B without materialising W', in
                     ``repro/kernels/lora_matmul.py`` (the TPU hot path).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import LoRAConfig, ModelConfig
from repro.parallel import ParamLeaf


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", str(last))


def is_target(path, leaf, lcfg: LoRAConfig) -> bool:
    shape = leaf.shape if hasattr(leaf, "shape") else ()
    return _leaf_name(path) in lcfg.targets and len(shape) >= 2


def init_lora(params, axes, cfg: ModelConfig, key=None, abstract: bool = False):
    """Build (lora_params, lora_axes) mirroring targeted leaves of ``params``."""
    lcfg = cfg.lora or LoRAConfig()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_axes = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda t: isinstance(t, tuple) and all(isinstance(e, (str, type(None))) for e in t)
    )[0]
    axes_by_path = {jax.tree_util.keystr(p): a for p, a in flat_axes}
    if key is None and not abstract:
        key = jax.random.PRNGKey(1)

    out_vals: dict[str, Any] = {}
    out_axes: dict[str, Any] = {}
    i = 0
    for path, leaf in flat:
        if not is_target(path, leaf, lcfg):
            continue
        pstr = jax.tree_util.keystr(path)
        w_axes = axes_by_path.get(pstr, tuple([None] * len(leaf.shape)))
        lead = tuple(leaf.shape[:-2])
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        r = lcfg.rank
        a_shape = lead + (d_in, r)
        b_shape = lead + (r, d_out)
        a_axes = tuple(w_axes[:-1]) + (None,)
        b_axes = tuple(w_axes[:-2]) + (None, w_axes[-1])
        if abstract:
            A = jax.ShapeDtypeStruct(a_shape, jnp.dtype(cfg.param_dtype))
            B = jax.ShapeDtypeStruct(b_shape, jnp.dtype(cfg.param_dtype))
        else:
            key, sub = jax.random.split(key)
            A = (jax.random.normal(sub, a_shape, jnp.float32) / r).astype(cfg.param_dtype)
            B = jnp.zeros(b_shape, cfg.param_dtype)  # Δw = 0 at init
        out_vals[pstr] = {"A": A, "B": B}
        out_axes[pstr] = {"A": a_axes, "B": b_axes}
        i += 1
    return out_vals, out_axes


def merge(params, lora_params, cfg: ModelConfig):
    """W' = W + (α/r)·A@B at every targeted leaf; other leaves pass through."""
    lcfg = cfg.lora or LoRAConfig()
    scale = lcfg.scale
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    merged = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        if pstr in lora_params:
            ab = lora_params[pstr]
            delta = jnp.einsum("...ir,...ro->...io", ab["A"].astype(jnp.float32),
                               ab["B"].astype(jnp.float32)) * scale
            merged.append((leaf.astype(jnp.float32) + delta).astype(leaf.dtype))
        else:
            merged.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, merged)


def delta_norm(lora_params) -> jax.Array:
    """||Δw||² across all adapters (diagnostics / convergence tracking)."""
    sq = [jnp.sum(jnp.square(v["A"].astype(jnp.float32))) + jnp.sum(jnp.square(v["B"].astype(jnp.float32)))
          for v in lora_params.values()]
    return jnp.sqrt(sum(sq))


def lora_param_count(cfg: ModelConfig) -> int:
    """Analytic adapter parameter count (used by the delay model: |Δw|)."""
    from repro.models.transformer import init_params

    params, axes = init_params(cfg, abstract=True)
    lcfg = cfg.lora or LoRAConfig()
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if is_target(path, leaf, lcfg):
            lead = 1
            for s in leaf.shape[:-2]:
                lead *= s
            total += lead * lcfg.rank * (leaf.shape[-2] + leaf.shape[-1])
    return total


def split_client_server(lora_params, cut_group: int):
    """Partition adapters at a scanned-group boundary: leaves under 'groups'
    keyed by stacked-layer dim are sliced; embed-side leaves go to the client,
    head/final-side to the server (paper: client holds the first A-fraction).
    """
    client, server = {}, {}
    for pstr, ab in lora_params.items():
        if "groups" in pstr:
            client[pstr] = jax.tree.map(lambda x: x[:cut_group], ab)
            server[pstr] = jax.tree.map(lambda x: x[cut_group:], ab)
        elif "embed" in pstr:
            client[pstr] = ab
        else:
            server[pstr] = ab
    return client, server


def join_client_server(client, server):
    """Inverse of split_client_server."""
    out = {}
    keys = set(client) | set(server)
    for pstr in keys:
        if pstr in client and pstr in server:
            out[pstr] = jax.tree.map(lambda c, s: jnp.concatenate([c, s], axis=0),
                                     client[pstr], server[pstr])
        elif pstr in client:
            out[pstr] = client[pstr]
        else:
            out[pstr] = server[pstr]
    return out
