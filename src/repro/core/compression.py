"""Uplink gradient/update compression (beyond-paper distributed-optimisation
trick).

The paper charges s_c = 28.1 kbit per client-side upload.  Top-k
sparsification with error feedback (memory) + int8 quantisation shrinks the
simulated uplink volume; ``compressed_bits`` feeds the delay model so the
resource allocator sees the smaller s_c.  Error feedback keeps convergence
(Karimireddy et al. 2019) — validated in tests by training with/without.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def topk_mask(x: jax.Array, fraction: float) -> jax.Array:
    """Keep the top-|fraction| entries by magnitude (per-leaf)."""
    n = x.size
    k = max(1, int(math.ceil(fraction * n)))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_tree(tree, fraction: float, error: Optional[dict] = None):
    """Top-k + error feedback. Returns (sparse_tree, new_error, bits)."""
    if error is None:
        error = jax.tree.map(jnp.zeros_like, tree)
    corrected = jax.tree.map(lambda g, e: g + e, tree, error)
    masks = jax.tree.map(lambda x: topk_mask(x, fraction), corrected)
    sparse = jax.tree.map(lambda x, m: x * m, corrected, masks)
    new_error = jax.tree.map(lambda x, s: x - s, corrected, sparse)
    bits = compressed_bits(tree, fraction)
    return sparse, new_error, bits


def quantize_int8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return q.astype(dtype) * scale


def compress_tree_int8(tree):
    """int8 quantise every leaf. Returns (q_tree, bits)."""
    q = jax.tree.map(lambda x: quantize_int8(x), tree)
    bits = sum(x.size * 8 + 32 for x in jax.tree.leaves(tree))
    return q, bits


def decompress_tree_int8(q_tree):
    return jax.tree.map(lambda qs: dequantize_int8(*qs), q_tree,
                        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)


def compressed_bits(tree, fraction: float, index_bits: Optional[int] = None,
                    value_bits: int = 32) -> float:
    """Uplink volume of a top-k sparsified tree (values + indices)."""
    total = 0.0
    for x in jax.tree.leaves(tree):
        n = x.size
        k = max(1, int(math.ceil(fraction * n)))
        ib = index_bits if index_bits is not None else max(1, math.ceil(math.log2(max(n, 2))))
        total += k * (value_bits + ib)
    return total


def dense_bits(tree, value_bits: int = 32) -> float:
    return float(sum(x.size for x in jax.tree.leaves(tree)) * value_bits)
