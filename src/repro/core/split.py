"""Split-learning engine (paper Algorithm 2).

The model is cut at a scanned-group boundary: the client executes
embed + groups[:cut]; the main server executes groups[cut:] + tail +
final-norm + head + loss.  Frozen base weights live on both sides (split-fed
deployments pre-stage w0; only LoRA updates and smashed activations move).

``split_value_and_grad`` reproduces the paper's message flow exactly with
``jax.vjp``:

    client forward  ->  smashed activations A_k   (uplink, s bits)
    server fwd+bwd  ->  loss, dLoRA_s, dA_k       (downlink gradient)
    client backward ->  dLoRA_c                   (vjp closure)

and is verified (tests/test_split.py) to equal end-to-end autodiff grads.
The activation byte count is exposed for the delay model (the paper's ``s``).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import lora as lora_lib
from repro.models import layers as L
from repro.models import transformer as T


class SplitParts(NamedTuple):
    client_base: Any  # params view with groups[:cut]
    server_base: Any  # params view with groups[cut:] (+ tail/final/head)


def slice_base(params, cut: int) -> SplitParts:
    client = dict(params)
    server = dict(params)
    client["groups"] = jax.tree.map(lambda a: a[:cut], params["groups"])
    server["groups"] = jax.tree.map(lambda a: a[cut:], params["groups"])
    return SplitParts(client, server)


def client_forward(client_base, lora_c, batch, cfg: ModelConfig, *, remat=False):
    """Embed + first ``cut`` groups -> smashed activations (B, S, D)."""
    merged = lora_lib.merge(client_base, lora_c, cfg)
    enc_out = T._run_encoder(merged, batch, cfg) if cfg.family == "encdec" else None
    x, positions = T._embed_inputs(merged, batch, cfg)
    x, _, _ = T._scan_groups(merged, x, cfg, positions=positions, enc_out=enc_out,
                             remat=remat, include_tail=False)
    return x, enc_out


def server_forward_loss(server_base, lora_s, acts, batch, cfg: ModelConfig, *,
                        enc_out=None, remat=False):
    """Remaining groups + tail + head + CE loss on the main server."""
    merged = lora_lib.merge(server_base, lora_s, cfg)
    S = acts.shape[1]
    positions = jnp.arange(S)[None, :]
    x, _, aux = T._scan_groups(merged, acts, cfg, positions=positions, enc_out=enc_out,
                               remat=remat, include_tail=True)
    x = L.apply_norm(merged["final_norm"], x, cfg)
    loss = L.fused_cross_entropy(merged["embed"], x, batch["labels"], cfg,
                                 mask=batch.get("mask"))
    return loss + 0.01 * aux


def split_value_and_grad(params, lora_c, lora_s, batch, cfg: ModelConfig, cut: int,
                         remat: bool = False, compressor=None):
    """Algorithm-2 message flow. Returns (loss, dlora_c, dlora_s, info).

    ``compressor`` (see ``repro.api.compressors``) is applied to the smashed
    activations on the client→server uplink, *outside* the client vjp: the
    server differentiates w.r.t. the compressed activations and the resulting
    dA_k flows straight through the codec back into the client backward pass
    (standard straight-through split learning).  ``info`` reports the exact
    per-trace compressed uplink volume for diagnostics; the delay model's
    ``s`` bits are rescaled by the codec's nominal ratio up front, in
    ``repro.api.Experiment`` (the allocator runs before any batch exists).
    """
    parts = slice_base(params, cut)

    def client_fn(lc):
        return client_forward(parts.client_base, lc, batch, cfg, remat=remat)

    (acts, enc_out), client_vjp = jax.vjp(client_fn, lora_c)
    if compressor is not None:
        acts = compressor.apply(acts)
        if enc_out is not None:  # encdec: the encoder output is uplink too
            enc_out = compressor.apply(enc_out)

    if enc_out is not None:  # encdec: encoder output is also smashed data
        def server_fn(ls, a, eo):
            return server_forward_loss(parts.server_base, ls, a, batch, cfg,
                                       enc_out=eo, remat=remat)

        loss, (dlora_s, dacts, denc) = jax.value_and_grad(server_fn, argnums=(0, 1, 2))(
            lora_s, acts, enc_out)
        (dlora_c,) = client_vjp((dacts, denc))
    else:
        def server_fn(ls, a):
            return server_forward_loss(parts.server_base, ls, a, batch, cfg,
                                       enc_out=None, remat=remat)

        loss, (dlora_s, dacts) = jax.value_and_grad(server_fn, argnums=(0, 1))(lora_s, acts)
        # gradient of smashed data returns to the client (the paper's dA_k)
        (dlora_c,) = client_vjp((dacts, None))
    uplink_elems = acts.size + (enc_out.size if enc_out is not None else 0)
    smashed_bits = (uplink_elems * acts.dtype.itemsize * 8 if compressor is None
                    else compressor.bits(uplink_elems, acts.dtype.itemsize * 8))
    info = {
        "smashed_bytes": uplink_elems * acts.dtype.itemsize,
        "smashed_bits_uplink": smashed_bits,
        "grad_bytes": dacts.size * dacts.dtype.itemsize,
    }
    return loss, dlora_c, dlora_s, info


def monolithic_value_and_grad(params, lora_c, lora_s, batch, cfg: ModelConfig, cut: int):
    """End-to-end autodiff reference — must equal split_value_and_grad."""

    def loss_fn(lc, ls):
        full = lora_lib.join_client_server(lc, ls)
        merged = lora_lib.merge(params, full, cfg)
        loss, _ = T.loss_fn(merged, batch, cfg)
        # note: T.loss_fn adds 0.01*aux internally; replicate server path
        return loss

    # simpler exact reference: run the same two-phase math in one graph
    def loss2(lc, ls):
        parts = slice_base(params, cut)
        acts, enc_out = client_forward(parts.client_base, lc, batch, cfg)
        return server_forward_loss(parts.server_base, ls, acts, batch, cfg, enc_out=enc_out)

    (loss), (dc, ds) = jax.value_and_grad(loss2, argnums=(0, 1))(lora_c, lora_s)
    return loss, dc, ds
