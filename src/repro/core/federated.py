"""Federated aggregation (the paper's fed-server role).

Algorithm 1: Δw_c^(n+1) = Δw_c^(n) + (1/K)·Σ_k h_c,k^(n); the main server
applies the same update to its server-side sub-models (Algorithm 2, last
line).  On the TPU mesh the "upload + aggregate + broadcast" becomes a mean
over the stacked client axis (lowered to an all-reduce over the ``data``/
``pod`` axes when clients are sharded).

Fault tolerance: ``fedavg`` takes an optional survivor ``mask`` so rounds
tolerate dropped / straggling clients (deadline-based straggler mitigation —
clients whose simulated wireless delay exceeds the round deadline simply
don't contribute, matching over-provisioned cohorts in production FL).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(stacked, weights: Optional[jax.Array] = None, mask: Optional[jax.Array] = None):
    """Weighted average over the leading client axis of every leaf.

    stacked: pytree with leaves (K, ...); weights: (K,) e.g. D_k (paper:
    weighted by data size); mask: (K,) 0/1 survivors."""
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return stacked
    K = leaves[0].shape[0]
    w = jnp.ones(K, jnp.float32) if weights is None else weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1e-12)
    wn = w / denom

    def one(x):
        wb = wn.reshape((K,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wb, axis=0).astype(x.dtype)

    return jax.tree.map(one, stacked)


def apply_update(global_tree, avg_h, scale: float = 1.0):
    """Δw ← Δw + scale·mean_k h_k (Algorithm 1 update)."""
    return jax.tree.map(
        lambda w, h: (w.astype(jnp.float32) + scale * h.astype(jnp.float32)).astype(w.dtype),
        global_tree, avg_h)


def broadcast(global_tree, K: int):
    """Fed-server broadcast: replicate the global model to K client slots."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), global_tree)


def client_sample(round_idx: int, num_clients: int, cohort: int, seed: int = 0) -> np.ndarray:
    """Per-round client sampling (elastic cohorts)."""
    rng = np.random.default_rng(seed * 1_000_003 + round_idx)
    return np.sort(rng.choice(num_clients, size=min(cohort, num_clients), replace=False))


def deadline_mask(T_k: np.ndarray, deadline: float) -> np.ndarray:
    """Straggler mitigation: survivors are clients meeting the deadline."""
    return (np.asarray(T_k) <= deadline).astype(np.float32)
