"""Federated aggregation (the paper's fed-server role).

Algorithm 1: Δw_c^(n+1) = Δw_c^(n) + (1/K)·Σ_k h_c,k^(n); the main server
applies the same update to its server-side sub-models (Algorithm 2, last
line).  On the TPU mesh the "upload + aggregate + broadcast" becomes a mean
over the stacked client axis (lowered to an all-reduce over the ``data``/
``pod`` axes when clients are sharded).

Fault tolerance: ``fedavg`` takes an optional survivor ``mask`` so rounds
tolerate dropped / straggling clients (deadline-based straggler mitigation —
clients whose simulated wireless delay exceeds the round deadline simply
don't contribute, matching over-provisioned cohorts in production FL).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(stacked, weights: Optional[jax.Array] = None, mask: Optional[jax.Array] = None):
    """Weighted average over the leading client axis of every leaf.

    stacked: pytree with leaves (K, ...); weights: (K,) e.g. D_k (paper:
    weighted by data size); mask: (K,) 0/1 survivors."""
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return stacked
    K = leaves[0].shape[0]
    w = jnp.ones(K, jnp.float32) if weights is None else weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(w), 1e-12)
    wn = w / denom

    def one(x):
        wb = wn.reshape((K,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(x.astype(jnp.float32) * wb, axis=0).astype(x.dtype)

    return jax.tree.map(one, stacked)


# fedavg is safe for the segment-sum two-tier fast path and uses `weights`
# when given (see hier_aggregate; "uniform" family members ignore weights)
fedavg.mean_family = "weighted"


def staleness_discount(staleness, beta: float = 0.5) -> np.ndarray:
    """Host-side staleness discount 1/(1+s)^β (numpy; the async schedule's
    per-arrival weight scale — multiplied onto D_k before the round fn)."""
    return (1.0 + np.asarray(staleness, float)) ** (-float(beta))


def staleness_weighted(stacked, weights: Optional[jax.Array] = None,
                       mask: Optional[jax.Array] = None,
                       staleness: Optional[jax.Array] = None,
                       beta: float = 0.5):
    """Staleness-aware FedAvg:  w_k ∝ D_k / (1 + staleness_k)^β.

    The asynchronous-aggregation rule (FedAsync / FedBuff): an update
    computed ``staleness`` global versions ago is polynomially discounted
    before the weighted average, so slow clients still contribute but never
    dominate fresh updates.  Mask-aware like every aggregator (masked-out
    clients contribute nothing regardless of staleness); ``staleness=None``
    degenerates to plain (weighted) fedavg, which is how the registered
    ``"staleness"`` aggregator behaves when the schedule passes the
    discount pre-folded into ``weights`` (``staleness_discount``)."""
    leaves = jax.tree.leaves(stacked)
    if not leaves or staleness is None:
        return fedavg(stacked, weights=weights, mask=mask)
    K = leaves[0].shape[0]
    w = jnp.ones(K, jnp.float32) if weights is None else weights.astype(jnp.float32)
    w = w * (1.0 + jnp.asarray(staleness, jnp.float32)) ** (-beta)
    return fedavg(stacked, weights=w, mask=mask)


staleness_weighted.mean_family = "weighted"


def _client_weight_mask(leaves, mask):
    """(K,) float mask broadcastable against each leaf of a stacked tree."""
    K = leaves[0].shape[0]
    m = jnp.ones(K, jnp.float32) if mask is None else mask.astype(jnp.float32)
    return K, m


def coordinate_median(stacked, weights: Optional[jax.Array] = None,
                      mask: Optional[jax.Array] = None):
    """Coordinate-wise median over the client axis (robust aggregation).

    Straggler-aware: masked-out clients are excluded from every coordinate's
    order statistic (NaN-dropped), not just down-weighted.  ``weights`` is
    accepted for aggregator-signature uniformity but ignored — the median is
    an unweighted order statistic (Yin et al. 2018)."""
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return stacked
    K, m = _client_weight_mask(leaves, mask)

    def one(x):
        xf = x.astype(jnp.float32)
        if mask is not None:
            mb = m.reshape((K,) + (1,) * (x.ndim - 1)) > 0
            xf = jnp.where(mb, xf, jnp.nan)
            med = jnp.nanmedian(xf, axis=0)
            # zero survivors (e.g. a round where every client missed the
            # deadline) must yield a zero update, not NaN-poison the state —
            # matching fedavg/trimmed_mean's graceful degradation
            med = jnp.where(jnp.sum(m) > 0, med, 0.0)
            return med.astype(x.dtype)
        return jnp.median(xf, axis=0).astype(x.dtype)

    return jax.tree.map(one, stacked)


def trimmed_mean(stacked, weights: Optional[jax.Array] = None,
                 mask: Optional[jax.Array] = None, trim: float = 0.2):
    """Coordinate-wise β-trimmed mean: drop the ⌊β·K⌋ largest and smallest
    values per coordinate, average the rest (robust to Byzantine/straggling
    outliers; Yin et al. 2018).

    Straggler-aware: masked-out clients are first replaced per-coordinate by
    the survivor mean so they occupy neither tail of the order statistic.
    ``weights`` is accepted for signature uniformity but ignored."""
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return stacked
    K, m = _client_weight_mask(leaves, mask)
    # static — K is the stacked client dim; trim at least one value per tail
    # when the cohort allows it, never more than keeps one survivor
    k_trim = min(int(np.ceil(trim * K)), (K - 1) // 2)

    def one(x):
        xf = x.astype(jnp.float32)
        if mask is not None:
            mb = m.reshape((K,) + (1,) * (x.ndim - 1))
            denom = jnp.maximum(jnp.sum(m), 1.0)
            surv_mean = jnp.sum(xf * mb, axis=0, keepdims=True) / denom
            xf = jnp.where(mb > 0, xf, surv_mean)
        xs = jnp.sort(xf, axis=0)
        kept = xs[k_trim: K - k_trim] if k_trim else xs
        return jnp.mean(kept, axis=0).astype(x.dtype)

    return jax.tree.map(one, stacked)


# edge count above which hier_aggregate's mean-family fast path switches
# from the bit-identical batched masked sums (O(M·K·leaf) broadcast) to the
# O(K·leaf) segment_sum scatter — the hundreds-of-edges regime, where no
# bit-compat contract with the old unrolled loop exists
SEGMENT_MIN_EDGES = 32


def hier_aggregate(aggregate, stacked, assign,
                   weights: Optional[jax.Array] = None,
                   mask: Optional[jax.Array] = None):
    """Two-tier reduction: per-edge aggregate, then aggregate across edges.

    The hierarchical (``edge-agg``) topology's fed-server role is split: each
    edge reduces its own clients' updates before the backhaul hop, the cloud
    reduces the edge aggregates.  ``assign`` is the cohort's one-hot
    membership matrix (K, M) — a *value-only* argument (static shape), so
    per-round re-attachment never retraces the round function.  Both tiers
    use the same base ``aggregate`` callable: membership enters tier 1 as a
    mask (composed with the straggler mask), and tier 2 weighs each edge by
    its surviving clients' total weight (empty cells are masked out).  For
    (weighted) fedavg the two-tier result equals the flat reduction up to
    float associativity; robust aggregators become per-edge robust.

    The mean-family aggregators (``fedavg``/``weighted``/``staleness`` —
    marked with a ``mean_family`` attribute) take a vectorised fast path
    whose trace size is independent of M (the unrolled loop builds M
    aggregate calls — fine at M=2, hopeless at M=64+).  Two regimes:

      * M ≤ ``SEGMENT_MIN_EDGES``: tier 1 is the SAME full-K masked sums
        the unrolled loop computes, batched over the edge axis — XLA fuses
        the one-hot broadcast into the reduction, and a batched reduce is
        BIT-IDENTICAL to the per-edge reduces (asserted exhaustively in
        ``tests/test_federated.py``), so existing edge-agg campaigns
        reproduce exactly;
      * M > ``SEGMENT_MIN_EDGES``: one ``jax.ops.segment_sum`` scatter-add
        over the client axis — O(K·leaf) memory instead of the batched
        path's O(M·K·leaf) broadcast, the regime hundreds-of-edges graphs
        need.  A scatter accumulates members sequentially while a
        vectorised reduce builds a SIMD tree, so this branch agrees with
        the unrolled loop only up to float associativity (≈1 ulp; exact
        whenever every cell has ≤ 2 surviving members) — no bit-compat
        contract exists at that scale.

    Robust aggregators (median/trimmed) keep the unrolled per-edge path —
    an order statistic has no segment reduction.
    """
    leaves = jax.tree.leaves(stacked)
    if not leaves:
        return stacked
    K, M = assign.shape
    w = jnp.ones(K, jnp.float32) if weights is None else weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    mode = getattr(aggregate, "mean_family", None)
    if mode is not None:
        base = (jnp.ones(K, jnp.float32)
                if (mode == "uniform" or weights is None)
                else weights.astype(jnp.float32))
        if M <= SEGMENT_MIN_EDGES:
            # (M, K) per-cell weight vectors, multiplied in the exact
            # order fedavg's unrolled calls would: base · (member · mask)
            cell = (assign.T if mask is None
                    else assign.T * mask.astype(jnp.float32)[None, :])
            w1 = base[None, :] * cell
            denom = jnp.maximum(jnp.sum(w1, axis=1), 1e-12)
            wn = w1 / denom[:, None]  # (M, K)

            def one(x):
                xf = x.astype(jnp.float32)
                wb = wn.reshape((M, K) + (1,) * (x.ndim - 1))
                return jnp.sum(xf[None] * wb, axis=1).astype(x.dtype)

            stacked_edges = jax.tree.map(one, stacked)
            ew = jnp.sum(w[None, :] * assign.T, axis=1)
        else:
            # one-hot rows -> member edge index (value-only, like assign)
            ids = jnp.argmax(assign, axis=1)
            w1 = base if mask is None else base * mask.astype(jnp.float32)
            denom = jnp.maximum(
                jax.ops.segment_sum(w1, ids, num_segments=M), 1e-12)
            wn = w1 / denom[ids]

            def one(x):
                wb = wn.reshape((K,) + (1,) * (x.ndim - 1))
                return jax.ops.segment_sum(x.astype(jnp.float32) * wb, ids,
                                           num_segments=M).astype(x.dtype)

            stacked_edges = jax.tree.map(one, stacked)
            ew = jax.ops.segment_sum(w, ids, num_segments=M)
    else:
        per_edge, edge_w = [], []
        for m in range(M):  # M is small and static — unrolled in the trace
            member = assign[:, m]
            cell_mask = member if mask is None else member * mask.astype(jnp.float32)
            per_edge.append(aggregate(stacked, weights=weights, mask=cell_mask))
            edge_w.append(jnp.sum(w * member))
        stacked_edges = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_edge)
        ew = jnp.stack(edge_w)
    return aggregate(stacked_edges, weights=ew, mask=(ew > 0).astype(jnp.float32))


def hier_aggregate_unrolled(aggregate, stacked, assign,
                            weights: Optional[jax.Array] = None,
                            mask: Optional[jax.Array] = None):
    """The reference unrolled two-tier reduction (M aggregate calls).

    Kept as the bit-equality oracle for the ``segment_sum`` fast path and as
    the only correct path for non-mean aggregators; ``hier_aggregate``
    dispatches here automatically for those."""
    stripped = _strip_mean_family(aggregate)
    return hier_aggregate(stripped, stacked, assign, weights=weights,
                          mask=mask)


def _strip_mean_family(aggregate):
    """A wrapper without the ``mean_family`` marker (forces the unrolled
    path) that leaves the aggregation arithmetic untouched."""

    def agg(stacked, weights=None, mask=None):
        return aggregate(stacked, weights=weights, mask=mask)

    return agg


def apply_update(global_tree, avg_h, scale: float = 1.0):
    """Δw ← Δw + scale·mean_k h_k (Algorithm 1 update)."""
    return jax.tree.map(
        lambda w, h: (w.astype(jnp.float32) + scale * h.astype(jnp.float32)).astype(w.dtype),
        global_tree, avg_h)


def broadcast(global_tree, K: int):
    """Fed-server broadcast: replicate the global model to K client slots."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), global_tree)


# population size above which client_sample switches from the legacy
# full-permutation draw (bit-identical — every pinned campaign golden lives
# at K ≤ 64) to Floyd's O(cohort) sampling: mega-scale campaigns must never
# materialise a length-K permutation per round (K=10⁵ × rounds would
# dominate the host-side loop — see repro.pop)
SAMPLE_MIN_CLIENTS = 64


def client_sample(round_idx: int, num_clients: int, cohort: int, seed: int = 0) -> np.ndarray:
    """Per-round client sampling (elastic cohorts), sorted and
    without replacement.

    ``num_clients ≤ SAMPLE_MIN_CLIENTS`` keeps the legacy
    ``Generator.choice`` permutation draw bit-identical; larger populations
    use Floyd's algorithm on the same per-round Generator stream — O(cohort)
    draws and memory, uniform over subsets, still a pure function of
    ``(round_idx, seed)``.
    """
    rng = np.random.default_rng(seed * 1_000_003 + round_idx)
    size = min(cohort, num_clients)
    if num_clients <= SAMPLE_MIN_CLIENTS:
        return np.sort(rng.choice(num_clients, size=size, replace=False))
    chosen: set = set()
    for j in range(num_clients - size, num_clients):
        t = int(rng.integers(0, j + 1))
        chosen.add(t if t not in chosen else j)
    return np.fromiter(sorted(chosen), np.int64, count=size)


def deadline_mask(T_k: np.ndarray, deadline: float) -> np.ndarray:
    """Straggler mitigation: survivors are clients meeting the deadline."""
    return (np.asarray(T_k) <= deadline).astype(np.float32)
