"""FedsLLM orchestration (paper Algorithms 1 + 2).

One *global round* (index n):
  1. broadcast global LoRA Δw = (Δw_c, Δw_s) to K clients,
  2. round-start gradients: g_k0 = ∇F_k(Δw) per client, ḡ = (1/K)Σ g_k0
     (the FEDL surrogate needs ∇F(Δw); this is the extra aggregation pass
     from ref. [11] that the paper's problem (4) inherits),
  3. local iterations i = 0..I_loc-1 on problem (4) by gradient descent
     (eq. 9):   h ← h − δ·∇G_k(h),
     ∇G_k(h) = ∇F_k(Δw+h) − ∇F_k(Δw) + ξ·∇F(Δw),
     where each ∇F_k evaluation is a *split* forward/backward (client fwd →
     smashed acts → server fwd/bwd → dA_k → client bwd),
  4. fed server + main server aggregate:  Δw ← Δw + (1/K)·Σ_k h_k
     (optionally masked for stragglers / dropped clients).

Clients are evaluated with ``jax.vmap`` over the stacked client axis, which
shards over the mesh ``data``(×``pod``) axes — client-parallelism *is* data
parallelism on the pod (DESIGN.md §3).

The number of local iterations follows Lemma 2 (v·log2(1/η)) and the number
of global rounds follows Lemma 1 (a/(1−η)); the simulated wall-clock cost of
each round comes from ``delay_model``/``resource_alloc``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedsLLMConfig, ModelConfig
from repro.core import delay_model as dm
from repro.core import federated, lora as lora_lib, split
from repro.models import transformer as T


class FedsLLMState(NamedTuple):
    base: Any  # frozen w0
    lora_c: Any  # global client-side adapters Δw_c
    lora_s: Any  # global server-side adapters Δw_s
    round: jax.Array  # global iteration n


def init_state(cfg: ModelConfig, cut: int, key=None) -> tuple[FedsLLMState, Any]:
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    base, axes = T.init_params(cfg, key=k1)
    lora_full, lora_axes = lora_lib.init_lora(base, axes, cfg, key=k2)
    lc, ls = lora_lib.split_client_server(lora_full, cut)
    return FedsLLMState(base, lc, ls, jnp.zeros((), jnp.int32)), (axes, lora_axes)


def local_iteration_count(fcfg: FedsLLMConfig, eta: float) -> int:
    # Lemma 2 lives in delay_model.local_iters (the allocator prices the
    # same count); this is the ⌈·⌉-with-floor the training scan uses
    return max(1, int(math.ceil(dm.local_iters(fcfg, eta))))


def global_round_count(fcfg: FedsLLMConfig, eta: float) -> int:
    return max(1, int(math.ceil(dm.lemma_a(fcfg) / (1.0 - eta))))


def build_round_fn(cfg: ModelConfig, fcfg: FedsLLMConfig, cut: int, eta: float,
                   xi: Optional[float] = None, delta: Optional[float] = None,
                   remat: bool = False, dp_clip: float = 0.0,
                   dp_noise: float = 0.0, aggregator: Optional[Callable] = None,
                   compressor=None, dp_seed: int = 0,
                   two_tier: bool = False, local_algo=None) -> Callable:
    """Build the jittable global-round function (the `repro.api` engine).

    round_fn(state, batches, mask=None, key=None, weights=None, assign=None)
        -> (state', metrics)
    batches: pytree with leaves stacked (K, ...) — one micro-dataset/client.
    mask: (K,) survivors (straggler tolerance), or None.
    weights: (K,) aggregation weights, e.g. data sizes D_k (paper's weighted
    FedAvg); None = uniform.
    assign: (K, M) one-hot client→edge membership — only consumed when
    ``two_tier=True`` (the ``edge-agg`` topology): every aggregation becomes
    per-edge then cross-edge (``federated.hier_aggregate``).  Like ``mask``
    it is a value-only argument: per-round re-attachment keeps one jit trace.
    aggregator: callable (stacked, weights=None, mask=None) -> tree; default
    ``federated.fedavg``.  Applied to both the round-start gradient average ḡ
    and the uploaded update average (Algorithm 1's fed-server reduction).
    compressor: optional ``repro.api.compressors.Compressor`` applied to the
    smashed activations on the client→server uplink (straight-through).
    dp_clip/dp_noise: per-client L2 clip + Gaussian noise multiplier on the
    uploaded updates (DP-FedAvg; the paper's noise-layer counterpart at the
    fed-server uplink). 0 disables.
    dp_seed: base seed of the DP noise stream.  When the caller passes
    ``key=None`` the per-round key is ``fold_in(PRNGKey(dp_seed),
    state.round)`` — fresh noise every global round (a fixed fallback key
    would silently reuse the same noise each round), derived inside the
    trace so multi-round campaigns keep a single jit compilation.

    The returned round_fn also takes ``update_scale=None`` — an optional
    scalar server mixing rate on the aggregated update (Δw ← Δw + α·h̄),
    the FedAsync-style damping the asynchronous execution schedules drive
    with α = 1/(1+staleness)^β.  A weight-vector discount alone cannot
    express it: the weighted mean NORMALIZES, so with a single surviving
    arrival any per-client discount cancels.  Pass a jnp scalar (value-only
    — one jit trace per campaign); ``None`` keeps the exact legacy
    arithmetic (α = 1).

    local_algo: the client local-update rule (``repro.fl.local_algos``
    name or instance); None/"gd" keeps the paper's plain GD on problem (4)
    bit-identically.  For a *stateful* algorithm (``scaffold``) the round
    function gains two trailing value-only arguments and returns a triple:

        round_fn(state, batches, mask, key, weights, assign, update_scale,
                 algo_state, algo_ids) -> (state', metrics, algo_state')

    ``algo_state``: the full-population ``(K, …)``-stacked control variates
    (carried across rounds by the caller); ``algo_ids``: (C,) int array
    mapping the cohort rows of ``batches`` onto population rows of
    ``algo_state`` (None = first C users).  Both are value-only — cohort
    gather/scatter happens inside the trace, so one jit trace per η bucket
    still covers elastic cohorts.  Variates update from the *raw* local
    deviations, before any DP clip/noise (the server-side c̄ needs the
    client's true trajectory; DP applies to the uplink, not local state).
    """
    from repro.fl.local_algos import get_local_algo

    xi = fcfg.xi if xi is None else xi
    delta = fcfg.delta if delta is None else delta
    I_loc = local_iteration_count(fcfg, eta)
    aggregate = federated.fedavg if aggregator is None else aggregator
    algo = get_local_algo("gd" if local_algo is None else local_algo)

    def client_grads(base, lc, ls, batch):
        loss, dc, ds, _ = split.split_value_and_grad(base, lc, ls, batch, cfg, cut,
                                                     remat=remat,
                                                     compressor=compressor)
        return loss, (dc, ds)

    def one_client_round(base, lc0, ls0, gk0, gbar, batch, ctrl=None,
                         ctrl_bar=None):
        """Local update on problem (4) for one client → (h_c, h_s, loss).

        The step rule is the selected local algorithm's: plain GD (eq. 9),
        FedProx's proximal pull, or SCAFFOLD's variate-corrected step
        (``ctrl``/``ctrl_bar`` carry this client's control variate and the
        population mean — None for stateless algorithms).
        """

        def grad_G(h):
            hc, hs = h
            lc = jax.tree.map(jnp.add, lc0, hc)
            ls = jax.tree.map(jnp.add, ls0, hs)
            loss, (dc, ds) = client_grads(base, lc, ls, batch)
            # ∇G = ∇F_k(Δw+h) − ∇F_k(Δw) + ξ∇F(Δw)
            gc = jax.tree.map(lambda a, b, c: a - b + xi * c, dc, gk0[0], gbar[0])
            gs = jax.tree.map(lambda a, b, c: a - b + xi * c, ds, gk0[1], gbar[1])
            return loss, (gc, gs)

        h0 = (jax.tree.map(jnp.zeros_like, lc0), jax.tree.map(jnp.zeros_like, ls0))

        def body(h, _):
            loss, g = grad_G(h)
            g = algo.correct(g, h, ctrl, ctrl_bar)
            h = jax.tree.map(lambda x, gx: x - delta * gx, h, g)
            return h, loss

        h, losses = jax.lax.scan(body, h0, None, length=I_loc)
        return h[0], h[1], losses[-1]

    def _round(state: FedsLLMState, batches, mask, key, weights, assign,
               update_scale, algo_state, algo_ids):
        K = jax.tree.leaves(batches)[0].shape[0]
        if two_tier and assign is not None:
            # hierarchical fed-server role: per-edge then cross-edge
            def agg(tree):
                return federated.hier_aggregate(aggregate, tree, assign,
                                                weights=weights, mask=mask)
        else:
            def agg(tree):
                return aggregate(tree, weights=weights, mask=mask)
        # 2. round-start gradients per client (h=0)
        loss0, g0 = jax.vmap(lambda b: client_grads(state.base, state.lora_c,
                                                    state.lora_s, b))(batches)
        # ḡ = ∇F(Δw) — fed-server aggregation (paper: uplink s_c per client)
        gbar = (agg(g0[0]), agg(g0[1]))

        # 3. local iterations (vmapped over clients)
        new_algo_state = algo_state
        if algo.stateful:
            if algo_state is None:
                raise ValueError(
                    f"local algo {algo.name!r} is stateful: pass algo_state= "
                    f"(the (K, …)-stacked control variates)")
            # c̄ over the full stored population; cohort rows gathered by
            # algo_ids — value-only, so elastic cohorts keep one trace
            ctrl_bar = jax.tree.map(lambda x: jnp.mean(x, axis=0), algo_state)
            ids = (jnp.arange(K, dtype=jnp.int32) if algo_ids is None
                   else algo_ids)
            ctrl = jax.tree.map(lambda x: x[ids], algo_state)
            h_c, h_s, last_loss = jax.vmap(
                lambda gk_c, gk_s, b, ck: one_client_round(
                    state.base, state.lora_c, state.lora_s, (gk_c, gk_s),
                    gbar, b, ctrl=ck, ctrl_bar=ctrl_bar)
            )(g0[0], g0[1], batches, ctrl)
            # variates advance on the RAW deviations (pre-DP); stragglers
            # keep theirs (the algo masks), then scatter back to the
            # population rows
            upd = algo.update_variates(ctrl, ctrl_bar, (h_c, h_s), mask,
                                       I_loc, delta)
            new_algo_state = jax.tree.map(
                lambda full, u: full.at[ids].set(u.astype(full.dtype)),
                algo_state, upd)
        else:
            h_c, h_s, last_loss = jax.vmap(
                lambda gk_c, gk_s, b: one_client_round(state.base, state.lora_c,
                                                       state.lora_s, (gk_c, gk_s), gbar, b)
            )(g0[0], g0[1], batches)

        # 3b. optional DP on the uploaded client updates
        if dp_clip > 0.0:
            from repro.core import privacy

            if key is None:
                key = jax.random.fold_in(jax.random.PRNGKey(dp_seed),
                                         state.round)
            h_c = privacy.clip_and_noise_updates(h_c, key, clip_norm=dp_clip,
                                                 noise_multiplier=dp_noise)

        # 4. aggregate + update (fed server for Δw_c, main server for Δw_s);
        # α = 1 (the paper's rule) unless an async schedule passes its
        # staleness mixing rate
        alpha = 1.0 if update_scale is None else update_scale
        new_lc = federated.apply_update(state.lora_c, agg(h_c), alpha)
        new_ls = federated.apply_update(state.lora_s, agg(h_s), alpha)
        metrics = {
            "loss_round_start": jnp.mean(loss0),
            "loss_local_final": jnp.mean(last_loss),
            # vmapped LoRA pytrees keep their dict structure, so delta_norm
            # applies directly to the stacked (K, ...) updates
            "h_c_norm": lora_lib.delta_norm(h_c),
        }
        new_state = FedsLLMState(state.base, new_lc, new_ls, state.round + 1)
        return new_state, metrics, new_algo_state

    # stateless algorithms keep the legacy signature and 2-tuple return
    # (the Python-level branch leaves the traced computation — and for
    # ``gd`` the jaxpr itself — bit-identical to the pre-registry engine);
    # stateful ones thread the variates through two extra value-only args
    if algo.stateful:
        def round_fn(state: FedsLLMState, batches, mask=None, key=None,
                     weights=None, assign=None, update_scale=None,
                     algo_state=None, algo_ids=None):
            return _round(state, batches, mask, key, weights, assign,
                          update_scale, algo_state, algo_ids)
    else:
        def round_fn(state: FedsLLMState, batches, mask=None, key=None,
                     weights=None, assign=None, update_scale=None):
            new_state, metrics, _ = _round(state, batches, mask, key, weights,
                                           assign, update_scale, None, None)
            return new_state, metrics

    round_fn.local_algo = algo
    return round_fn


# ---------------------------------------------------------------------------
# Simulated wall-clock integration (delay model + allocator)
# ---------------------------------------------------------------------------


@dataclass
class RoundTiming:
    """Per-global-round simulated wireless wall-clock (seconds)."""

    compute: np.ndarray  # (K,) eq. (10)
    uplink_fed: np.ndarray  # (K,) t_c
    uplink_main: np.ndarray  # (K,) V·t_s
    total: np.ndarray  # (K,)


def simulate_round_time(fcfg: FedsLLMConfig, net, alloc, eta: float,
                        model_params: Optional[int] = None) -> RoundTiming:
    V = dm.local_iters(fcfg, eta)
    tau = dm.compute_time(fcfg, net, eta, alloc.A, model_params)
    up_f = alloc.t_c
    up_m = V * alloc.t_s
    return RoundTiming(tau, up_f, up_m, tau + up_f + up_m)
