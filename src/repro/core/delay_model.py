"""FedsLLM training-delay model (paper §III, eqs. 8–15) + wireless channel.

Implements, exactly as in the paper:
  * Lemma 1:  I0 = a/(1-η),  a = (2L²/γ²ξ)·ln(1/ε0)      (global rounds)
  * Lemma 2:  i ≥ v·log2(1/η),  v = 2/((2-Lδ)δγ)          (local iterations)
  * eq. (10): τ_k = E_k·log2(1/η)·(A/f_k + (1-A)/f_s),  E_k = v|w|C_k D_k
  * eq. (11): r = b·log2(1 + g·p/(N·b))                    (FDMA rate)
  * eq. (15): T_k = I0·(τ_k + t_c,k + v·log2(1/η)·t_s,k)

Channel realisation follows §IV: K users uniform in a 500 m square around
the BS, path loss 128.1 + 37.6·log10(d_km) dB, 8 dB log-normal shadowing,
N0 = −174 dBm/Hz, C_k ~ U[1,3]·1e4 cycles, p_max = 10 dBm, f_max = 2 GHz.
All math is numpy (host-side — this is the simulator that drives the
resource allocator, not device compute).
"""

from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.config import FedsLLMConfig


# ---------------------------------------------------------------------------
# Units
# ---------------------------------------------------------------------------


def dbm_to_watt(dbm: float) -> float:
    return 10.0 ** (dbm / 10.0) / 1000.0


def db_to_lin(db: float) -> float:
    return 10.0 ** (db / 10.0)


# ---------------------------------------------------------------------------
# Network realisation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Network:
    """One sampled wireless network + client heterogeneity realisation."""

    g_c: np.ndarray  # (K,) linear channel gains to fed server
    g_s: np.ndarray  # (K,) linear channel gains to main server
    C_k: np.ndarray  # (K,) cycles per (sample·param)
    D_k: np.ndarray  # (K,) local dataset sizes
    f_max: np.ndarray  # (K,) client CPU Hz
    p_c_max: np.ndarray  # (K,) W
    p_s_max: np.ndarray  # (K,) W
    N0: float  # W/Hz
    B_c: float  # Hz
    B_s: float  # Hz
    f_server: float  # Hz
    # provenance (filled by realize_network; None on the legacy all-at-once
    # draw) — lets scenario tests assert geometry invariants across rounds
    xy: Optional[np.ndarray] = None  # (K, 2) user positions, metres
    pl_db: Optional[np.ndarray] = None  # (K,) distance path loss, dB

    @property
    def K(self) -> int:
        return len(self.g_c)


@dataclass(frozen=True)
class LargeScaleState:
    """Everything about the network that outlives one fading block.

    Drawn once per campaign (``sample_large_scale``) and held fixed — or
    evolved by a mobility step — while the small-scale fading is redrawn
    every round (``realize_network``).  The legacy ``sample_network`` path
    conflates the two (it redraws positions with every call); scenarios that
    promise geometry invariance compose these two halves instead.
    """

    xy: np.ndarray  # (K, 2) user positions, metres (BS at origin)
    pl_db: np.ndarray  # (K,) distance path loss, dB
    C_k: np.ndarray  # (K,) cycles per (sample·param)
    D_k: np.ndarray  # (K,) local dataset sizes
    f_max: np.ndarray  # (K,) client CPU Hz
    p_c_max: np.ndarray  # (K,) W
    p_s_max: np.ndarray  # (K,) W
    N0: float  # W/Hz
    B_c: float  # Hz
    B_s: float  # Hz
    f_server: float  # Hz

    @property
    def K(self) -> int:
        return len(self.pl_db)

    @property
    def digest(self) -> str:
        """Content hash of the large-scale realisation (checkpoint identity:
        resuming a campaign under different geometry/heterogeneity is a
        different campaign and must be refused)."""
        h = hashlib.sha1()
        for a in (self.xy, self.pl_db, self.C_k, self.D_k, self.f_max,
                  self.p_c_max, self.p_s_max):
            h.update(np.ascontiguousarray(np.asarray(a, float)).tobytes())
        h.update(np.asarray([self.N0, self.B_c, self.B_s, self.f_server],
                            float).tobytes())
        return h.hexdigest()[:16]


def path_loss_db(cfg: FedsLLMConfig, xy: np.ndarray) -> np.ndarray:
    """Distance path loss 128.1 + 37.6·log10(d_km) for positions (K, 2), m."""
    d_km = np.maximum(np.linalg.norm(xy, axis=1), 1.0) / 1000.0  # ≥1 m
    return cfg.pathloss_const_db + cfg.pathloss_exp * np.log10(d_km)


def sample_large_scale(cfg: FedsLLMConfig, seed: int = 0,
                       p_max_dbm: float | None = None) -> LargeScaleState:
    """Draw the once-per-campaign state: geometry + client heterogeneity.

    Same distributions as ``sample_network`` (§IV), but no channel gains —
    those are small-scale and belong to ``realize_network``.
    """
    rng = np.random.default_rng(seed)
    K = cfg.num_clients
    half = cfg.area_m / 2.0
    xy = rng.uniform(-half, half, size=(K, 2))
    p = dbm_to_watt(cfg.p_max_dbm if p_max_dbm is None else p_max_dbm)
    return LargeScaleState(
        xy=xy,
        pl_db=path_loss_db(cfg, xy),
        C_k=rng.uniform(cfg.cycles_per_param_low, cfg.cycles_per_param_high, size=K),
        D_k=np.full(K, cfg.num_samples // K, dtype=float),
        f_max=np.full(K, cfg.f_max_hz),
        p_c_max=np.full(K, p),
        p_s_max=np.full(K, p),
        N0=dbm_to_watt(cfg.noise_psd_dbm_hz),
        B_c=cfg.bandwidth_total_hz,
        B_s=cfg.bandwidth_total_hz,
        f_server=cfg.f_server_hz,
    )


def realize_network(cfg: FedsLLMConfig, ls: LargeScaleState, seed: int,
                    extra_loss_db: Optional[np.ndarray] = None,
                    shadow_db: Optional[np.ndarray] = None) -> Network:
    """One small-scale (per-round) realisation over fixed large-scale state.

    Redraws only the log-normal shadowing on both links, keyed by ``seed``;
    geometry, path loss and client heterogeneity come from ``ls`` unchanged.
    ``extra_loss_db`` (K,) adds a deterministic per-user deep-fade penalty on
    top (the ``outage`` scenario's burst loss) — applied to both links.
    ``shadow_db`` (2, K) overrides the i.i.d. shadowing draw with caller-
    provided per-link fields (row 0 → fed link, row 1 → main link) — the
    ``shadowing`` scenario's temporally-correlated AR(1) process; the RNG is
    then not consumed, so the existing i.i.d. draw order stays bit-frozen.
    """
    rng = np.random.default_rng(seed)
    K = ls.K
    extra = 0.0 if extra_loss_db is None else np.asarray(extra_loss_db, float)

    def gains(link: int):
        shadow = (rng.normal(0.0, cfg.shadow_std_db, size=K)
                  if shadow_db is None else np.asarray(shadow_db[link], float))
        return db_to_lin(-(ls.pl_db + shadow + extra))

    # copies, not views: callers mutate Network arrays in place (e.g. D_k
    # reweighting) and ``ls`` may be cached/shared across rounds
    return Network(
        g_c=gains(0),
        g_s=gains(1),
        C_k=ls.C_k.copy(),
        D_k=ls.D_k.copy(),
        f_max=ls.f_max.copy(),
        p_c_max=ls.p_c_max.copy(),
        p_s_max=ls.p_s_max.copy(),
        N0=ls.N0,
        B_c=ls.B_c,
        B_s=ls.B_s,
        f_server=ls.f_server,
        xy=ls.xy.copy(),
        pl_db=ls.pl_db.copy(),
    )


def sample_network(cfg: FedsLLMConfig, seed: int = 0, p_max_dbm: float | None = None) -> Network:
    """Legacy all-at-once draw: geometry + heterogeneity + gains in one shot.

    BIT-FROZEN: the ``frozen``/``blockfade`` scenarios and every pre-scenario
    campaign are keyed to this exact RNG consumption order — do not reorder
    the draws.  New scenario families compose ``sample_large_scale`` +
    ``realize_network`` instead, which separate what persists across rounds
    from what fades.
    """
    rng = np.random.default_rng(seed)
    K = cfg.num_clients
    half = cfg.area_m / 2.0
    xy = rng.uniform(-half, half, size=(K, 2))
    d_km = np.maximum(np.linalg.norm(xy, axis=1), 1.0) / 1000.0  # ≥1 m

    def gains():
        pl_db = cfg.pathloss_const_db + cfg.pathloss_exp * np.log10(d_km)
        shadow = rng.normal(0.0, cfg.shadow_std_db, size=K)
        return db_to_lin(-(pl_db + shadow))

    p = dbm_to_watt(cfg.p_max_dbm if p_max_dbm is None else p_max_dbm)
    # even sample split (paper: equal selection probability)
    D = np.full(K, cfg.num_samples // K, dtype=float)
    return Network(
        g_c=gains(),
        g_s=gains(),
        C_k=rng.uniform(cfg.cycles_per_param_low, cfg.cycles_per_param_high, size=K),
        D_k=D,
        f_max=np.full(K, cfg.f_max_hz),
        p_c_max=np.full(K, p),
        p_s_max=np.full(K, p),
        N0=dbm_to_watt(cfg.noise_psd_dbm_hz),  # W/Hz
        B_c=cfg.bandwidth_total_hz,
        B_s=cfg.bandwidth_total_hz,
        f_server=cfg.f_server_hz,
    )


# ---------------------------------------------------------------------------
# Lemma constants
# ---------------------------------------------------------------------------


def lemma_a(cfg: FedsLLMConfig) -> float:
    """a = (2L²/γ²ξ)·ln(1/ε0)  (Lemma 1)."""
    return 2.0 * cfg.L_smooth**2 / (cfg.gamma_strong**2 * cfg.xi) * np.log(1.0 / cfg.epsilon0)


def lemma_v(cfg: FedsLLMConfig) -> float:
    """v = 2/((2-Lδ)δγ)  (Lemma 2); requires δ < 2/L."""
    assert cfg.delta < 2.0 / cfg.L_smooth
    return 2.0 / ((2.0 - cfg.L_smooth * cfg.delta) * cfg.delta * cfg.gamma_strong)


def global_rounds(cfg: FedsLLMConfig, eta: float) -> float:
    return lemma_a(cfg) / (1.0 - eta)


def local_iters(cfg: FedsLLMConfig, eta: float) -> float:
    return lemma_v(cfg) * np.log2(1.0 / eta)


# ---------------------------------------------------------------------------
# Delay terms
# ---------------------------------------------------------------------------


def compute_time(cfg: FedsLLMConfig, net: Network, eta: float, A: float,
                 model_params: int | None = None) -> np.ndarray:
    """eq. (10): per-client compute time per global round (K,)."""
    w = float(model_params if model_params is not None else cfg.sample_dim)
    E_k = lemma_v(cfg) * w * net.C_k * net.D_k
    return E_k * np.log2(1.0 / eta) * (A / net.f_max + (1.0 - A) / net.f_server)


def rate(b: np.ndarray, g: np.ndarray, p: np.ndarray, N0: float) -> np.ndarray:
    """eq. (11): FDMA uplink rate, bits/s.  Safe at b -> 0 (limit 0)."""
    b = np.asarray(b, float)
    out = np.zeros_like(b)
    pos = b > 0
    out[pos] = b[pos] * np.log2(1.0 + g[pos] * p[pos] / (N0 * b[pos]))
    return out


def rate_scalar(b: float, g: float, p: float, N0: float) -> float:
    if b <= 0:
        return 0.0
    return b * np.log2(1.0 + g * p / (N0 * b))


def bandwidth_for_rate(r_req: np.ndarray, g: np.ndarray, p: np.ndarray, N0: float) -> np.ndarray:
    """Invert eq. (11) in closed form via Lambert W.

    r = b·log2(1 + c/b), c = g·p/N0.  With t = c/b and q = r·ln2/c ∈ (0,1):
    ln(1+t) = q·t  ⇒  t = −W₋₁(−q·e^{−q})/q − 1,  b = c/t.
    rate(b) is increasing & concave with limit c/ln2; returns +inf where
    r_req exceeds that capacity (infeasible regardless of bandwidth)."""
    from scipy.special import lambertw

    r_req = np.asarray(r_req, float)
    c = g * p / N0  # received SNR-per-Hz numerator
    q = r_req * np.log(2.0) / np.maximum(c, 1e-300)
    out = np.full_like(r_req, np.inf)
    zero = r_req <= 0
    ok = (~zero) & (q < 1.0 - 1e-12)
    if np.any(ok):
        qq = q[ok]
        w = np.real(lambertw(-qq * np.exp(-qq), k=-1))
        t = -w / qq - 1.0
        out[ok] = c[ok] / np.maximum(t, 1e-300)
    out[zero] = 0.0
    return out


def round_latency(cfg: FedsLLMConfig, net: Network, eta: float, A: float,
                  t_c: np.ndarray, t_s: np.ndarray,
                  model_params: int | None = None) -> np.ndarray:
    """eq. (15): total training latency per client, T_k (K,)."""
    I0 = global_rounds(cfg, eta)
    V = local_iters(cfg, eta)
    tau = compute_time(cfg, net, eta, A, model_params)
    return I0 * (tau + t_c + V * t_s)


def energy(cfg: FedsLLMConfig, net: Network, eta: float, A: float,
           t_c: np.ndarray, t_s: np.ndarray, model_params: int | None = None) -> np.ndarray:
    """Per-client energy (κ·f²·cycles + p·t), for diagnostics/extensions."""
    w = float(model_params if model_params is not None else cfg.sample_dim)
    V = local_iters(cfg, eta)
    cycles = V * np.log2(1.0 / eta) * w * net.C_k * net.D_k * A
    e_cmp = cfg.kappa * net.f_max**2 * cycles
    e_tx = net.p_c_max * t_c + net.p_s_max * V * t_s
    return global_rounds(cfg, eta) * (e_cmp + e_tx)
