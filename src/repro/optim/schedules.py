"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        w = jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
        return lr * w

    return fn


def cosine_with_warmup(lr: float, warmup_steps: int, total_steps: int,
                       final_frac: float = 0.1):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (s + 1.0) / max(warmup_steps, 1))
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos

    return fn
