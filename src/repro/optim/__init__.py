from repro.optim.optimizers import Optimizer, adamw, adafactor, sgd
from repro.optim.schedules import constant, cosine_with_warmup, linear_warmup
from repro.optim.grad_utils import clip_by_global_norm, global_norm

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "sgd",
    "constant",
    "cosine_with_warmup",
    "linear_warmup",
    "clip_by_global_norm",
    "global_norm",
]
