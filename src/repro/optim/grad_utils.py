"""Gradient utilities: global-norm clipping, accumulation."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(sq) if sq else jnp.zeros(()))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), gn


def accumulate(microbatch_grads):
    """Mean of a list of grad trees (gradient accumulation)."""
    n = len(microbatch_grads)
    out = microbatch_grads[0]
    for g in microbatch_grads[1:]:
        out = jax.tree.map(jnp.add, out, g)
    return jax.tree.map(lambda x: x / n, out)
