"""Pure-JAX optimizers (no optax in this environment).

Each optimizer is a pair of pure functions (init, update) over pytrees.
Moments are kept in ``moment_dtype`` (fp32 by default) while params may be
bf16 — the update math runs in fp32 and casts back (mixed-precision
training).  Moment tensors inherit the *parameter* sharding (ZeRO-style:
since params are FSDP-sharded over ``data``, optimizer state is too).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def sgd(lr: Callable | float, momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0, moment_dtype="float32") -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def one(g, p, m=None):
            g32 = g.astype(jnp.float32)
            if weight_decay:
                g32 = g32 + weight_decay * p.astype(jnp.float32)
            if m is None:
                return (p.astype(jnp.float32) - lr_t * g32).astype(p.dtype), None
            m_new = momentum * m.astype(jnp.float32) + g32
            step_dir = g32 + momentum * m_new if nesterov else m_new
            return ((p.astype(jnp.float32) - lr_t * step_dir).astype(p.dtype),
                    m_new.astype(moment_dtype))

        if momentum == 0.0:
            new_params = jax.tree.map(lambda g, p: one(g, p)[0], grads, params)
            return new_params, state
        out = jax.tree.map(one, grads, params, state["m"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m}

    return Optimizer(init, update)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, moment_dtype="float32") -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def one(g, p, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mh = m_new / c1
            vh = v_new / c2
            upd = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * upd).astype(p.dtype),
                    m_new.astype(moment_dtype), v_new.astype(moment_dtype))

        out = jax.tree.map(one, grads, params, state["m"], state["v"])
        isleaf = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], out, is_leaf=isleaf),
                {"m": jax.tree.map(lambda t: t[1], out, is_leaf=isleaf),
                 "v": jax.tree.map(lambda t: t[2], out, is_leaf=isleaf)})

    return Optimizer(init, update)


def adafactor(lr: Callable | float, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments for >=2D params (memory: O(m+n) not O(mn)).

    Used for the very largest configs (qwen3-235b) where full AdamW moments
    dominate HBM; see EXPERIMENTS.md §Perf."""
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def one(g, p, f):
            g32 = g.astype(jnp.float32)
            sq = g32 * g32 + eps
            if g.ndim >= 2:
                r = beta * f["r"] + (1 - beta) * jnp.mean(sq, axis=-1)
                c = beta * f["c"] + (1 - beta) * jnp.mean(sq, axis=-2)
                rc = r / jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), eps)
                vhat = rc[..., None] * c[..., None, :]
                upd = g32 / jnp.sqrt(vhat + eps)
                new_f = {"r": r, "c": c}
            else:
                v = beta * f["v"] + (1 - beta) * sq
                upd = g32 / jnp.sqrt(v + eps)
                new_f = {"v": v}
            rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype), new_f

        isleaf_f = lambda t: isinstance(t, dict) and ("r" in t or "v" in t)
        out = jax.tree.map(one, grads, params, state["f"], is_leaf=None)
        isleaf = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], out, is_leaf=isleaf),
                {"f": jax.tree.map(lambda t: t[1], out, is_leaf=isleaf)})

    return Optimizer(init, update)


def get_optimizer(name: str, lr, cfg=None) -> Optimizer:
    if name == "adamw":
        kw = {}
        if cfg is not None:
            kw = dict(b1=cfg.beta1, b2=cfg.beta2, eps=cfg.eps, weight_decay=cfg.weight_decay,
                      moment_dtype=cfg.moment_dtype)
        return adamw(lr, **kw)
    if name == "sgd":
        return sgd(lr)
    if name == "adafactor":
        return adafactor(lr)
    raise ValueError(name)
